//! In-process serving loop: submit -> future-like handle -> response.
//!
//! A serve session is scoped ([`serve`] wraps [`pool::run_service`]):
//! `workers` service threads each hold a [`Runtime::for_worker`] handle
//! (so any artifact compile goes through the process-wide
//! `runtime::exe_cache` exactly once) plus a worker-tagged [`EventLog`];
//! the caller's `body` closure drives traffic through a [`ServerHandle`].
//! When `body` returns, partial batches flush, the queue closes, workers
//! drain it, and the session's [`ServeSummary`] is computed and emitted.
//!
//! Two modes:
//! - **fifo** (deterministic, for tests): batches form purely from the
//!   submission sequence (`max_batch` or an explicit flush); no wall
//!   clock is consulted, so a seeded driver produces a byte-identical
//!   response log at any worker count;
//! - **timed**: submissions also flush any buffer whose oldest request
//!   has waited past `max_wait_us`, trading determinism for bounded
//!   batching delay.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::events::EventLog;
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::util::pool::{self, Service, TaskCtx};

use super::registry::{CacheStats, Registry};
use super::scheduler::{
    Batch, Batcher, BatchPolicy, PendingRequest, Response, ResponseHandle,
};

#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    pub workers: usize,
    pub policy: BatchPolicy,
    /// Deterministic mode: never consult the wall clock for batching.
    pub fifo: bool,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig { workers: 1, policy: BatchPolicy::default(), fifo: true }
    }
}

// --------------------------------------------------------------- metrics ---

struct Metrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    /// Outstanding requests (submitted, not yet responded) — the queue
    /// depth gauge; covers batcher buffers, the service queue, and
    /// requests on a worker.
    outstanding: AtomicUsize,
    max_outstanding: AtomicUsize,
    shared_client_workers: AtomicUsize,
    lat_ns: Mutex<Vec<u64>>,
    per_tenant_ns: Mutex<std::collections::BTreeMap<String, Vec<u64>>>,
    batch_sizes: Mutex<std::collections::BTreeMap<usize, u64>>,
}

impl Metrics {
    fn new() -> Metrics {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            outstanding: AtomicUsize::new(0),
            max_outstanding: AtomicUsize::new(0),
            shared_client_workers: AtomicUsize::new(0),
            lat_ns: Mutex::new(Vec::new()),
            per_tenant_ns: Mutex::new(std::collections::BTreeMap::new()),
            batch_sizes: Mutex::new(std::collections::BTreeMap::new()),
        }
    }

    fn note_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        let depth = self.outstanding.fetch_add(1, Ordering::Relaxed) + 1;
        self.max_outstanding.fetch_max(depth, Ordering::Relaxed);
    }

    fn note_batch(&self, size: usize) {
        *self.batch_sizes.lock().unwrap().entry(size).or_insert(0) += 1;
    }

    /// Per-request hot path: atomics only. Latencies are buffered
    /// per-worker (in [`WorkerState`]) and merged once at worker exit,
    /// so completing a request never takes a process-global lock.
    fn note_complete_counts(&self) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.outstanding.fetch_sub(1, Ordering::Relaxed);
    }

    /// One worker's buffered latencies, merged at its exit.
    fn merge_worker(&self, lat_ns: Vec<u64>,
                    per_tenant: std::collections::BTreeMap<String, Vec<u64>>) {
        self.lat_ns.lock().unwrap().extend(lat_ns);
        let mut all = self.per_tenant_ns.lock().unwrap();
        for (tenant, ns) in per_tenant {
            all.entry(tenant).or_default().extend(ns);
        }
    }

    fn note_failed(&self, n: usize) {
        self.failed.fetch_add(n as u64, Ordering::Relaxed);
        self.outstanding.fetch_sub(n, Ordering::Relaxed);
    }

    fn summarize(&self, workers: usize, wall_s: f64, cache: CacheStats)
                 -> ServeSummary {
        let mut lat = self.lat_ns.lock().unwrap().clone();
        lat.sort_unstable();
        let completed = self.completed.load(Ordering::Relaxed);
        let tenants = self.per_tenant_ns.lock().unwrap().iter()
            .map(|(tenant, ns)| {
                let mut ns = ns.clone();
                ns.sort_unstable();
                TenantSummary {
                    tenant: tenant.clone(),
                    requests: ns.len() as u64,
                    p50_us: percentile_us(&ns, 50.0),
                    p95_us: percentile_us(&ns, 95.0),
                    p99_us: percentile_us(&ns, 99.0),
                }
            })
            .collect();
        ServeSummary {
            workers,
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            failed: self.failed.load(Ordering::Relaxed),
            wall_s,
            rps: if wall_s > 0.0 { completed as f64 / wall_s } else { 0.0 },
            p50_us: percentile_us(&lat, 50.0),
            p95_us: percentile_us(&lat, 95.0),
            p99_us: percentile_us(&lat, 99.0),
            max_queue_depth: self.max_outstanding.load(Ordering::Relaxed),
            shared_client_workers: self.shared_client_workers.load(Ordering::Relaxed),
            batch_hist: self.batch_sizes.lock().unwrap().iter()
                .map(|(&s, &c)| (s, c)).collect(),
            cache,
            tenants,
        }
    }
}

/// Nearest-rank percentile over a sorted nanosecond vector, in µs.
fn percentile_us(sorted_ns: &[u64], p: f64) -> f64 {
    if sorted_ns.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted_ns.len() as f64 - 1.0)).round() as usize;
    sorted_ns[idx.min(sorted_ns.len() - 1)] as f64 / 1_000.0
}

#[derive(Clone, Debug)]
pub struct TenantSummary {
    pub tenant: String,
    pub requests: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

/// End-of-session metrics: global and per-tenant latency percentiles,
/// throughput, queue depth, batch-size histogram, cache counters.
#[derive(Clone, Debug)]
pub struct ServeSummary {
    pub workers: usize,
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub wall_s: f64,
    pub rps: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub max_queue_depth: usize,
    pub shared_client_workers: usize,
    /// (batch size, batches dispatched at that size), ascending.
    pub batch_hist: Vec<(usize, u64)>,
    pub cache: CacheStats,
    pub tenants: Vec<TenantSummary>,
}

impl ServeSummary {
    /// Export through the event log: one `serve_summary` line, one
    /// `serve_tenant` line per tenant.
    pub fn emit(&self, log: &EventLog) {
        let hist = Json::Arr(self.batch_hist.iter()
            .map(|&(s, c)| Json::Arr(vec![s.into(), Json::Num(c as f64)]))
            .collect());
        log.emit("serve_summary", vec![
            ("workers", self.workers.into()),
            ("submitted", Json::Num(self.submitted as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("rps", Json::Num(self.rps)),
            ("p50_us", Json::Num(self.p50_us)),
            ("p95_us", Json::Num(self.p95_us)),
            ("p99_us", Json::Num(self.p99_us)),
            ("max_queue_depth", self.max_queue_depth.into()),
            ("shared_client_workers", self.shared_client_workers.into()),
            ("batch_hist", hist),
            ("cache_hits", Json::Num(self.cache.hits as f64)),
            ("cache_misses", Json::Num(self.cache.misses as f64)),
            ("cache_evictions", Json::Num(self.cache.evictions as f64)),
            ("cache_bytes", self.cache.bytes.into()),
            ("cache_capacity_bytes", self.cache.capacity_bytes.into()),
        ]);
        for t in &self.tenants {
            log.emit("serve_tenant", vec![
                ("tenant", t.tenant.as_str().into()),
                ("requests", Json::Num(t.requests as f64)),
                ("p50_us", Json::Num(t.p50_us)),
                ("p95_us", Json::Num(t.p95_us)),
                ("p99_us", Json::Num(t.p99_us)),
            ]);
        }
    }

    /// Human-readable one-screen report for the CLI.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "served {} requests in {:.3}s with {} worker(s): {:.0} req/s \
             ({} failed)",
            self.completed, self.wall_s, self.workers, self.rps, self.failed);
        let _ = writeln!(
            s,
            "latency p50 {:.1}µs  p95 {:.1}µs  p99 {:.1}µs  \
             max queue depth {}",
            self.p50_us, self.p95_us, self.p99_us, self.max_queue_depth);
        let hist: Vec<String> = self.batch_hist.iter()
            .map(|&(sz, c)| format!("{sz}x{c}"))
            .collect();
        let _ = writeln!(s, "batch sizes [{}]", hist.join(" "));
        let _ = writeln!(
            s,
            "mat cache: {} hits / {} misses / {} evictions, {} / {} bytes \
             ({} entries)",
            self.cache.hits, self.cache.misses, self.cache.evictions,
            self.cache.bytes, self.cache.capacity_bytes, self.cache.entries);
        s
    }
}

// ---------------------------------------------------------------- server ---

/// What `body` gets: the submission side of a live serve session.
pub struct ServerHandle<'a> {
    registry: &'a Registry,
    service: &'a Service<Batch>,
    metrics: &'a Metrics,
    batcher: Mutex<Batcher>,
    fifo: bool,
}

impl ServerHandle<'_> {
    /// Admit one request. Validates tenant and input dimension up front;
    /// the returned handle resolves when a worker serves the batch this
    /// request lands in.
    pub fn submit(&self, tenant: &str, meta: u64, input: Vec<f32>)
                  -> Result<ResponseHandle> {
        let snap = self.registry.snapshot(tenant)?;
        if input.len() != snap.spec.dim() {
            bail!("tenant {tenant:?}: input has {} elements, adapter dim is {}",
                  input.len(), snap.spec.dim());
        }
        let guard = self.registry.begin(tenant)?;
        let (req, handle) = PendingRequest::new(meta, input, guard);
        self.metrics.note_submit();
        let full = self.batcher.lock().unwrap().push(tenant, req);
        if let Some(batch) = full {
            self.dispatch(batch);
        }
        if !self.fifo {
            self.flush_expired();
        }
        Ok(handle)
    }

    /// Dispatch every buffer that has outwaited the policy (timed mode).
    pub fn flush_expired(&self) {
        let expired = self.batcher.lock().unwrap().take_expired(Instant::now());
        for batch in expired {
            self.dispatch(batch);
        }
    }

    /// Dispatch all partial batches now (the closed-loop driver calls
    /// this at each wave boundary; `serve` calls it after `body`).
    pub fn flush(&self) {
        let drained = self.batcher.lock().unwrap().drain();
        for batch in drained {
            self.dispatch(batch);
        }
    }

    /// Outstanding requests: buffered + queued + on a worker.
    pub fn queue_depth(&self) -> usize {
        self.metrics.outstanding.load(Ordering::Relaxed)
    }

    pub fn registry(&self) -> &Registry {
        self.registry
    }

    fn dispatch(&self, batch: Batch) {
        self.metrics.note_batch(batch.requests.len());
        self.service.push(batch);
    }
}

struct WorkerState<'a> {
    /// Held for the session: on real PJRT bindings this is where batch
    /// execution compiles/loads artifacts, exactly-once per process via
    /// the shared exe_cache. The pure-Rust Q_P path needs no compiles.
    _wrt: crate::runtime::WorkerRuntime<'a>,
    log: EventLog,
    metrics: &'a Metrics,
    /// Worker-local latency buffers — merged into `metrics` on drop so
    /// the per-request path stays lock-free (see `note_complete_counts`).
    lat_ns: Vec<u64>,
    per_tenant_ns: std::collections::BTreeMap<String, Vec<u64>>,
}

impl Drop for WorkerState<'_> {
    fn drop(&mut self) {
        self.metrics.merge_worker(
            std::mem::take(&mut self.lat_ns),
            std::mem::take(&mut self.per_tenant_ns));
    }
}

/// out = x @ Q_P for one request row (Q_P row-major [n, n]).
fn apply_row(input: &[f32], qp: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0f32; n];
    for (k, &xv) in input.iter().enumerate() {
        let row = &qp[k * n..(k + 1) * n];
        for (o, &w) in out.iter_mut().zip(row) {
            *o += xv * w;
        }
    }
    out
}

fn process_batch(registry: &Registry, metrics: &Metrics,
                 state: &mut WorkerState<'_>, ctx: TaskCtx, batch: Batch) {
    // resolve the adapter at service time: an immutable snapshot, so a
    // concurrent hot-swap can never tear version/params mid-batch
    let snap = match registry.snapshot(&batch.tenant) {
        Ok(s) => s,
        Err(e) => return fail_batch(metrics, &state.log, ctx, batch, &e.to_string()),
    };
    let qp = match registry.materialized(&snap) {
        Ok(m) => m,
        Err(e) => return fail_batch(metrics, &state.log, ctx, batch, &e.to_string()),
    };
    let n = snap.spec.dim();
    let tenant_lat = state.per_tenant_ns.entry(batch.tenant.clone()).or_default();
    for req in batch.requests {
        if req.input.len() != n {
            let msg = format!(
                "tenant {:?}: input has {} elements but the live adapter \
                 (version {}) has dim {n}",
                batch.tenant, req.input.len(), snap.version);
            metrics.note_failed(1);
            req.fail(msg);
            continue;
        }
        let output = apply_row(&req.input, &qp, n);
        let latency_ns = req.submitted.elapsed().as_nanos() as u64;
        metrics.note_complete_counts();
        state.lat_ns.push(latency_ns);
        tenant_lat.push(latency_ns);
        let meta = req.meta;
        req.complete(Response {
            meta,
            tenant: batch.tenant.clone(),
            version: snap.version,
            checksum: snap.checksum,
            output,
            latency_us: latency_ns as f64 / 1_000.0,
        });
    }
}

fn fail_batch(metrics: &Metrics, log: &EventLog, ctx: TaskCtx, batch: Batch,
              msg: &str) {
    log.emit("serve_error", vec![
        ("tenant", batch.tenant.as_str().into()),
        ("batch_index", ctx.index.into()),
        ("requests", batch.requests.len().into()),
        ("error", msg.into()),
    ]);
    metrics.note_failed(batch.requests.len());
    for req in batch.requests {
        req.fail(msg.to_string());
    }
}

/// A completed serve session: whatever `body` returned, plus the metrics.
pub struct ServeOutcome<R> {
    pub body: R,
    pub summary: ServeSummary,
}

/// Run a scoped serve session (see the module docs). The summary is
/// emitted through `log` before returning.
pub fn serve<R, F>(rt: &Runtime, registry: &Registry, cfg: &ServeConfig,
                   log: &EventLog, body: F) -> Result<ServeOutcome<R>>
where
    F: FnOnce(&ServerHandle<'_>) -> Result<R>,
{
    let metrics = Metrics::new();
    let t0 = Instant::now();
    let (body_result, init_errors): (Result<R>, Vec<String>) = pool::run_service(
        cfg.workers,
        |w| {
            let wrt = rt.for_worker(w)?;
            if wrt.is_shared() {
                metrics.shared_client_workers.fetch_add(1, Ordering::Relaxed);
            }
            Ok(WorkerState {
                _wrt: wrt,
                log: log.for_worker(w),
                metrics: &metrics,
                lat_ns: Vec::new(),
                per_tenant_ns: std::collections::BTreeMap::new(),
            })
        },
        |state, ctx, batch: Batch| process_batch(registry, &metrics, state, ctx, batch),
        |service| {
            let handle = ServerHandle {
                registry,
                service,
                metrics: &metrics,
                batcher: Mutex::new(Batcher::new(cfg.policy)),
                fifo: cfg.fifo,
            };
            let r = if cfg.fifo {
                body(&handle)
            } else {
                // timed mode's max-wait bound must hold even when no
                // further submit arrives to piggyback a flush on: a
                // flusher thread sweeps expired buffers on a half-wait
                // cadence for the whole session
                let stop = AtomicBool::new(false);
                let tick = Duration::from_micros(
                    (cfg.policy.max_wait_us / 2).max(50));
                std::thread::scope(|s| {
                    s.spawn(|| {
                        while !stop.load(Ordering::Relaxed) {
                            handle.flush_expired();
                            std::thread::sleep(tick);
                        }
                    });
                    let r = catch_unwind(AssertUnwindSafe(|| body(&handle)));
                    stop.store(true, Ordering::Relaxed);
                    match r {
                        Ok(r) => r,
                        Err(p) => resume_unwind(p),
                    }
                })
            };
            handle.flush();
            r
        },
    );
    let wall_s = t0.elapsed().as_secs_f64();
    // worker-init failures are the root cause behind any "request
    // dropped unserved" errors the body saw — log them and attach them
    // to the body's error instead of discarding the diagnosis
    for e in &init_errors {
        log.emit("serve_error", vec![("error", e.as_str().into())]);
    }
    let body_value = match body_result {
        Ok(v) => v,
        Err(e) if !init_errors.is_empty() => {
            return Err(e.context(format!(
                "serve worker(s) failed to start: [{}]",
                init_errors.join("; "))));
        }
        Err(e) => return Err(e),
    };
    let summary = metrics.summarize(cfg.workers, wall_s, registry.cache_stats());
    summary.emit(log);
    Ok(ServeOutcome { body: body_value, summary })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quantum::pauli;
    use crate::serve::registry::PauliSpec;

    fn test_registry() -> Registry {
        let reg = Registry::new(1 << 22);
        let spec = PauliSpec { q: 3, n_layers: 1 };
        let thetas: Vec<f32> = (0..spec.num_params())
            .map(|i| (i as f32 * 0.31).sin())
            .collect();
        reg.register("t0", spec, thetas).unwrap();
        reg
    }

    #[test]
    fn serve_round_trip_matches_direct_apply() {
        let reg = test_registry();
        let rt = Runtime::cpu().unwrap();
        let cfg = ServeConfig { workers: 2, ..ServeConfig::default() };
        let input: Vec<f32> = (0..8).map(|i| (i as f32 * 0.7).cos()).collect();
        let outcome = serve(&rt, &reg, &cfg, &EventLog::null(), |h| {
            let r = h.submit("t0", 7, input.clone())?;
            h.flush();
            r.wait()
        }).unwrap();
        let resp = outcome.body;
        assert_eq!(resp.meta, 7);
        assert_eq!(resp.version, 1);
        // the served output is exactly x @ Q_P for the registered thetas
        let snap = reg.snapshot("t0").unwrap();
        let c = pauli::build(3, 1);
        let mut expect = input.clone();
        c.apply(&mut expect, 1, &snap.thetas);
        for (a, b) in resp.output.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        assert_eq!(outcome.summary.completed, 1);
        assert_eq!(outcome.summary.failed, 0);
        assert_eq!(outcome.summary.max_queue_depth, 1);
    }

    #[test]
    fn unknown_tenant_and_bad_dim_fail_at_submit() {
        let reg = test_registry();
        let rt = Runtime::cpu().unwrap();
        let cfg = ServeConfig::default();
        serve(&rt, &reg, &cfg, &EventLog::null(), |h| {
            assert!(h.submit("nope", 0, vec![0.0; 8]).is_err());
            assert!(h.submit("t0", 0, vec![0.0; 7]).is_err());
            Ok(())
        }).unwrap();
    }

    #[test]
    fn unwaited_requests_resolve_on_session_end() {
        // submit without flush: serve()'s end-of-body flush dispatches
        // the partial batch; the handle resolves after the session
        let reg = test_registry();
        let rt = Runtime::cpu().unwrap();
        let cfg = ServeConfig::default();
        let outcome = serve(&rt, &reg, &cfg, &EventLog::null(), |h| {
            h.submit("t0", 3, vec![0.5; 8])
        }).unwrap();
        let resp = outcome.body.wait().unwrap();
        assert_eq!(resp.meta, 3);
        assert_eq!(outcome.summary.submitted, 1);
    }

    #[test]
    fn percentiles_are_sane() {
        let ns: Vec<u64> = (1..=100).map(|i| i * 1000).collect();
        assert!((percentile_us(&ns, 50.0) - 51.0).abs() < 2.0);
        assert!((percentile_us(&ns, 99.0) - 99.0).abs() < 2.0);
        assert_eq!(percentile_us(&[], 50.0), 0.0);
    }
}
