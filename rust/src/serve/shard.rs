//! Sharded serving tier: N independent shard instances behind a
//! consistent-hash router.
//!
//! Each shard owns the full single-instance serving stack — its own
//! [`Registry`] (adapter slots + mat-cache LRU), its own
//! batcher/scheduler and worker pool (one scoped [`serve`] session per
//! shard), its own admission ledger, and (optionally) its own
//! [`StateStore`] durability dir under `<state_root>/shard-NNNN`. The
//! [`ShardRouter`] in front hashes tenant names onto a virtual-node ring
//! (FNV-1a, [`crate::util::fnv`]) so placement is a pure function of
//! (tenant name, shard count) — no coordination, no lookup service.
//!
//! ## Determinism
//!
//! Routing is deterministic, and each shard is a normal fifo serve
//! session, so the single-instance byte-identity guarantee *composes*:
//! a seeded driver submitting sequentially produces, per shard, a
//! deterministic submission subsequence, hence byte-identical per-shard
//! response logs at any worker count. Commands reach a shard through one
//! FIFO channel (submits are synchronous round-trips), so batch
//! composition on every shard is a pure function of the driver's
//! submission order.
//!
//! ## Live migration
//!
//! [`ShardRouter::migrate`] moves one tenant between shards without
//! dropping in-flight requests:
//! 1. the adapter is re-registered on the target at its *recorded*
//!    version — write-ahead into the target's WAL (a `Register` record),
//!    then [`Registry::restore`] so the version/checksum pair served by
//!    the target is byte-identical to the source's;
//! 2. the routing table flips atomically (an override entry under a
//!    write lock): new submissions land on the target;
//! 3. the source pin-drains: its batcher is flushed so buffered requests
//!    dispatch, and [`Registry::try_evict_tenant`] retries while the
//!    [`RequestGuard`](super::registry::RequestGuard) pins report
//!    [`EvictAttempt::Deferred`]; the final eviction appends the `Evict`
//!    record to the source's WAL.
//! Every in-flight request completes on whichever shard admitted it, and
//! both shards serve identical (version, checksum, output) triples, so a
//! mid-run migration leaves the merged meta-sorted response log
//! byte-identical to a no-migration control over the same admitted set.
//!
//! ## Shard failure and recovery
//!
//! [`ShardRouter::kill_shard`] ends a shard's session and drops its
//! registry and store handles; requests routing to a dead shard shed
//! with the typed [`Rejected`] reason
//! [`RejectReason::ShardDown`] while every other shard keeps serving.
//! [`ShardRouter::restart_shard`] re-opens the shard's *own* state dir,
//! replays its WAL/snapshot, restores exactly the tenants that shard
//! owned at their recorded versions, and starts a fresh session.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::events::EventLog;
use crate::runtime::Runtime;
use crate::store::{Durability, StateRecord, StateStore, TenantState};
use crate::util::fnv;
use crate::util::json::Json;
use crate::util::sync::{lock_or_recover, read_or_recover, write_or_recover};

use super::admission::{RejectReason, Rejected};
use super::registry::{EvictAttempt, Registry};
use super::scheduler::ResponseHandle;
use super::server::{q_json, q_us, serve, ServeConfig, ServeSummary, SloSummary,
                    SubmitTarget};
use crate::obs::TenantSloStatus;

/// Virtual nodes per shard on the hash ring: enough that tenant load
/// spreads evenly at small shard counts, cheap enough that building the
/// ring is negligible (`shards * 64` u64 sorts).
const VNODES_PER_SHARD: usize = 64;

/// Fleet shape: how many shards, and what each shard's serving stack
/// looks like. Every field except `shards` applies *per shard*.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    pub shards: usize,
    /// Per-shard serve session config (`workers` is workers per shard).
    pub serve: ServeConfig,
    /// Per-shard materialization-cache byte budget.
    pub cache_bytes: usize,
    /// Per-tenant quota within each shard's cache (0 = off).
    pub tenant_quota_bytes: usize,
    /// When set, each shard persists its mutations to its own
    /// [`StateStore`] under `<state_root>/shard-NNNN` — the recovery
    /// source for [`ShardRouter::restart_shard`].
    pub state_root: Option<PathBuf>,
    /// WAL fsync cadence for the per-shard stores.
    pub durability: Durability,
}

impl Default for ShardConfig {
    fn default() -> ShardConfig {
        ShardConfig {
            shards: 4,
            serve: ServeConfig::default(),
            cache_bytes: 8 << 20,
            tenant_quota_bytes: 0,
            state_root: None,
            durability: Durability::Buffered,
        }
    }
}

/// The per-shard durable state dir under a fleet root.
pub fn shard_state_dir(root: &std::path::Path, shard: usize) -> PathBuf {
    root.join(format!("shard-{shard:04}"))
}

// --------------------------------------------------------------- routing ---

/// Consistent-hash ring (sorted vnode hashes -> shard index) plus the
/// migration overrides. Swapped atomically under a `RwLock`: readers
/// (every submit) take the read lock, a migration flip takes the write
/// lock once.
struct RoutingTable {
    ring: Vec<(u64, usize)>,
    overrides: BTreeMap<String, usize>,
}

fn build_ring(shards: usize) -> Vec<(u64, usize)> {
    let mut ring = Vec::with_capacity(shards * VNODES_PER_SHARD);
    for s in 0..shards {
        for v in 0..VNODES_PER_SHARD {
            let key = format!("shard-{s}-vnode-{v}");
            ring.push((fnv::hash(key.as_bytes()), s));
        }
    }
    ring.sort_unstable();
    ring
}

impl RoutingTable {
    fn new(shards: usize) -> RoutingTable {
        RoutingTable { ring: build_ring(shards), overrides: BTreeMap::new() }
    }

    /// Successor-vnode lookup (wrapping), after the override map.
    fn route(&self, tenant: &str) -> usize {
        if let Some(&s) = self.overrides.get(tenant) {
            return s;
        }
        let h = fnv::hash(tenant.as_bytes());
        let i = self.ring.partition_point(|&(k, _)| k < h);
        self.ring[if i == self.ring.len() { 0 } else { i }].1
    }
}

// -------------------------------------------------------------- commands ---

/// One driver->shard command. A shard consumes its queue in FIFO order
/// on its own control thread, inside its serve session's `body`.
enum ShardCmd {
    Submit {
        tenant: String,
        meta: u64,
        input: Vec<f32>,
        reply: Sender<Result<ResponseHandle>>,
    },
    Flush,
    Advance { dt_s: f64 },
    /// Metrics-interval tick (fifo mode): the shard checks whether its
    /// completion count crossed an interval mark and emits the
    /// `serve_interval` snapshot. Acked so the router can serialize
    /// ticks across shards (deterministic EventLog interleaving).
    Tick { done: Sender<()> },
    /// End the current serve session (the session flushes and drains
    /// in-flight work before its summary is reported).
    Stop,
}

/// Control-plane message for a shard's lifecycle thread.
enum ShardRun {
    /// Start a serve session over this registry.
    Start { registry: Arc<Registry> },
    /// Exit the lifecycle thread.
    Shutdown,
}

/// Everything the router keeps per shard. `registry`/`store` are
/// `None` while the shard is dead (killed, not yet restarted).
struct ShardSeat {
    cmd_tx: Sender<ShardCmd>,
    run_tx: Sender<ShardRun>,
    registry: Mutex<Option<Arc<Registry>>>,
    store: Mutex<Option<Arc<StateStore>>>,
    alive: AtomicBool,
}

/// Build one shard's registry (and durable store, when configured),
/// restoring any recovered tenants at their recorded versions. Returns
/// the recovered tenant names.
fn build_shard_registry(cfg: &ShardConfig, shard: usize, log: &EventLog)
                        -> Result<(Arc<Registry>, Option<Arc<StateStore>>,
                                   Vec<String>)> {
    let mut registry = Registry::new(cfg.cache_bytes)
        .with_tenant_quota(cfg.tenant_quota_bytes);
    let mut recovered_names = Vec::new();
    let store = match &cfg.state_root {
        Some(root) => {
            let dir = shard_state_dir(root, shard);
            let opened = StateStore::open(&dir, cfg.durability)
                .with_context(|| format!("open shard {shard} state dir \
                                          {dir:?}"))?;
            for ts in &opened.recovered.tenants {
                registry.restore(ts).with_context(|| {
                    format!("shard {shard}: restoring recovered tenant {:?}",
                            ts.tenant)
                })?;
                recovered_names.push(ts.tenant.clone());
            }
            log.emit("shard_state_recovered", vec![
                ("shard", shard.into()),
                ("dir", dir.display().to_string().into()),
                ("tenants", opened.recovered.tenants.len().into()),
                ("wal_records", Json::Num(opened.recovered.wal_records as f64)),
                ("torn_tail", opened.recovered.torn_tail.to_string().into()),
            ]);
            let store = Arc::new(opened.store);
            registry = registry.with_state_sink(store.clone());
            Some(store)
        }
        None => None,
    };
    Ok((Arc::new(registry), store, recovered_names))
}

// ---------------------------------------------------------------- router ---

/// What `body` gets from [`serve_sharded`]: the routing/submission front
/// of the fleet, plus the rebalance and failure/recovery controls.
pub struct ShardRouter<'a> {
    cfg: &'a ShardConfig,
    log: &'a EventLog,
    table: RwLock<RoutingTable>,
    seats: Vec<ShardSeat>,
    results_rx: Mutex<Receiver<(usize, Result<ServeSummary>)>>,
    /// Session summaries already collected (e.g. by `kill_shard`).
    collected: Mutex<Vec<(usize, ServeSummary)>>,
    /// Serve sessions started so far — how many results to expect.
    started: AtomicUsize,
}

impl ShardRouter<'_> {
    pub fn shards(&self) -> usize {
        self.seats.len()
    }

    /// Where `tenant` routes right now (ring + migration overrides).
    pub fn shard_of(&self, tenant: &str) -> usize {
        read_or_recover(&self.table).route(tenant)
    }

    pub fn is_alive(&self, shard: usize) -> bool {
        self.seats[shard].alive.load(Ordering::Acquire)
    }

    /// The shard's registry (tenant registration, inspection). Errors
    /// while the shard is dead.
    pub fn registry(&self, shard: usize) -> Result<Arc<Registry>> {
        let seat = self.seats.get(shard)
            .with_context(|| format!("no shard {shard}"))?;
        lock_or_recover(&seat.registry).clone()
            .with_context(|| format!("shard {shard} is down"))
    }

    fn shed(&self, tenant: &str) -> anyhow::Error {
        Rejected {
            tenant: tenant.to_string(),
            reason: RejectReason::ShardDown,
        }
        .into()
    }

    /// Route and submit one request. A dead shard sheds with the typed
    /// [`Rejected`] reason [`RejectReason::ShardDown`] instead of
    /// queueing behind it. The call is a synchronous round-trip to the
    /// shard's control thread, so per-shard submission order is exactly
    /// the caller's submission order — the determinism guarantee.
    pub fn submit(&self, tenant: &str, meta: u64, input: Vec<f32>)
                  -> Result<ResponseHandle> {
        let shard = self.shard_of(tenant);
        let seat = &self.seats[shard];
        if !seat.alive.load(Ordering::Acquire) {
            return Err(self.shed(tenant));
        }
        let (reply_tx, reply_rx) = channel();
        let cmd = ShardCmd::Submit {
            tenant: tenant.to_string(),
            meta,
            input,
            reply: reply_tx,
        };
        if seat.cmd_tx.send(cmd).is_err() {
            return Err(self.shed(tenant));
        }
        match reply_rx.recv() {
            Ok(r) => r,
            // the session ended under us (shard killed with the command
            // queued): the request was never admitted — shed it
            Err(_) => Err(self.shed(tenant)),
        }
    }

    /// Flush partial batches on every live shard (shard order, so fifo
    /// runs stay deterministic).
    pub fn flush(&self) {
        for seat in &self.seats {
            if seat.alive.load(Ordering::Acquire) {
                let _ = seat.cmd_tx.send(ShardCmd::Flush);
            }
        }
    }

    /// Advance every live shard's logical admission clock (fifo mode).
    pub fn advance_clock(&self, dt_s: f64) {
        for seat in &self.seats {
            if seat.alive.load(Ordering::Acquire) {
                let _ = seat.cmd_tx.send(ShardCmd::Advance { dt_s });
            }
        }
    }

    pub fn is_fifo(&self) -> bool {
        self.cfg.serve.fifo
    }

    /// Metrics-interval tick, fanned out to every live shard *in shard
    /// order, waiting for each ack* — so the `serve_interval` lines from
    /// different shards never interleave and fifo EventLogs stay
    /// byte-identical at any worker count.
    pub fn tick(&self) {
        for seat in &self.seats {
            if !seat.alive.load(Ordering::Acquire) {
                continue;
            }
            let (done_tx, done_rx) = channel();
            if seat.cmd_tx.send(ShardCmd::Tick { done: done_tx }).is_ok() {
                let _ = done_rx.recv();
            }
        }
    }

    /// Live-migrate one tenant to `target` without dropping in-flight
    /// requests (see the module docs for the three-step protocol).
    pub fn migrate(&self, tenant: &str, target: usize) -> Result<()> {
        if target >= self.shards() {
            bail!("migrate {tenant:?}: no shard {target} \
                   (fleet has {})", self.shards());
        }
        let source = self.shard_of(tenant);
        if source == target {
            return Ok(());
        }
        let src = self.registry(source)
            .with_context(|| format!("migrate {tenant:?}: source shard \
                                      {source} is down"))?;
        let dst = self.registry(target)
            .with_context(|| format!("migrate {tenant:?}: target shard \
                                      {target} is down"))?;
        // 1. re-register on the target at the *recorded* version:
        // write-ahead into the target's WAL, then install — the same
        // record/replay discipline the registry itself uses, so a target
        // restart recovers the migrated tenant
        let snap = src.snapshot(tenant)?;
        let ts = TenantState {
            tenant: tenant.to_string(),
            version: snap.version,
            q: snap.spec.q,
            n_layers: snap.spec.n_layers,
            checksum: snap.checksum,
            path: snap.origin.clone(),
            thetas: snap.thetas.as_ref().clone(),
        };
        if let Some(store) = lock_or_recover(&self.seats[target].store).as_ref() {
            store.append(&StateRecord::Register(ts.clone()))
                .with_context(|| format!("migrate {tenant:?}: write-ahead \
                                          to shard {target}"))?;
        }
        dst.restore(&ts)
            .with_context(|| format!("migrate {tenant:?}: install on shard \
                                      {target}"))?;
        // 2. atomic routing flip: every submission from here on lands on
        // the target, which serves the identical (version, checksum)
        write_or_recover(&self.table)
            .overrides.insert(tenant.to_string(), target);
        // 3. pin-drain the source: flush so its buffered requests
        // dispatch, then retry while in-flight RequestGuard pins defer
        // the eviction; the Evict record lands in the source's WAL
        loop {
            match src.try_evict_tenant(tenant)? {
                EvictAttempt::Evicted | EvictAttempt::Unknown => break,
                EvictAttempt::Deferred(_) => {
                    let _ = self.seats[source].cmd_tx.send(ShardCmd::Flush);
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
        }
        self.log.emit("shard_migrate", vec![
            ("tenant", tenant.into()),
            ("from", source.into()),
            ("to", target.into()),
            ("version", Json::Num(ts.version as f64)),
        ]);
        Ok(())
    }

    /// Stop one shard's serve session and drop its registry and store
    /// handles (closing its WAL). In-flight work drains before the
    /// session ends; afterwards the shard's tenants shed with
    /// [`RejectReason::ShardDown`] until [`restart_shard`](Self::restart_shard).
    pub fn kill_shard(&self, shard: usize) -> Result<ServeSummary> {
        let seat = self.seats.get(shard)
            .with_context(|| format!("no shard {shard}"))?;
        if !seat.alive.swap(false, Ordering::AcqRel) {
            bail!("shard {shard} is already down");
        }
        seat.cmd_tx.send(ShardCmd::Stop)
            .ok().context("shard control thread is gone")?;
        let summary = self.recv_result_for(shard)?;
        // keep the session in `collected` too: shutdown expects exactly
        // `started` results, and this one just left the channel
        lock_or_recover(&self.collected).push((shard, summary.clone()));
        // release the shard's handles: the WAL file closes, so a restart
        // re-opens and replays the shard's own state dir cleanly
        *lock_or_recover(&seat.registry) = None;
        *lock_or_recover(&seat.store) = None;
        self.log.emit("shard_killed", vec![("shard", shard.into())]);
        Ok(summary)
    }

    /// Restart a dead shard from its own state dir: replay snapshot +
    /// WAL, restore its tenants at their recorded versions, start a new
    /// serve session. Returns the restored tenant names (empty when the
    /// fleet runs without `state_root`).
    pub fn restart_shard(&self, shard: usize) -> Result<Vec<String>> {
        let seat = self.seats.get(shard)
            .with_context(|| format!("no shard {shard}"))?;
        if seat.alive.load(Ordering::Acquire) {
            bail!("shard {shard} is already serving");
        }
        let (registry, store, recovered) =
            build_shard_registry(self.cfg, shard, self.log)?;
        *lock_or_recover(&seat.registry) = Some(registry.clone());
        *lock_or_recover(&seat.store) = store;
        self.started.fetch_add(1, Ordering::AcqRel);
        seat.run_tx.send(ShardRun::Start { registry })
            .ok().context("shard lifecycle thread is gone")?;
        seat.alive.store(true, Ordering::Release);
        self.log.emit("shard_restarted", vec![
            ("shard", shard.into()),
            ("tenants", recovered.len().into()),
        ]);
        Ok(recovered)
    }

    /// Block until the session result for `shard` arrives, stashing any
    /// other shard's result (sessions can end concurrently at shutdown).
    fn recv_result_for(&self, shard: usize) -> Result<ServeSummary> {
        let rx = lock_or_recover(&self.results_rx);
        loop {
            // analyze: allow(blocking-under-lock) the results_rx mutex exists only to serialize receivers; blocking in recv while holding it is the design
            let (idx, res) = rx.recv()
                .ok().context("shard session results channel closed")?;
            let summary = res.with_context(|| {
                format!("shard {idx} serve session failed")
            })?;
            if idx == shard {
                return Ok(summary);
            }
            lock_or_recover(&self.collected).push((idx, summary));
        }
    }
}

impl SubmitTarget for ShardRouter<'_> {
    fn submit(&self, tenant: &str, meta: u64, input: Vec<f32>)
              -> Result<ResponseHandle> {
        ShardRouter::submit(self, tenant, meta, input)
    }

    fn flush(&self) {
        ShardRouter::flush(self)
    }

    fn advance_clock(&self, dt_s: f64) {
        ShardRouter::advance_clock(self, dt_s)
    }

    fn is_fifo(&self) -> bool {
        ShardRouter::is_fifo(self)
    }

    fn tick(&self) {
        ShardRouter::tick(self)
    }
}

// ----------------------------------------------------------- fleet scope ---

/// A completed fleet run: whatever `body` returned plus one
/// [`ServeSummary`] per serve *session* (a restarted shard contributes
/// one per session), tagged with the shard index.
pub struct ShardOutcome<R> {
    pub body: R,
    pub sessions: Vec<(usize, ServeSummary)>,
}

/// One shard's lifecycle loop: run serve sessions over whatever
/// registries the router hands it, reporting each session's summary.
fn shard_main(shard: usize, rt: &Runtime, cfg: &ShardConfig, log: &EventLog,
              run_rx: Receiver<ShardRun>, cmd_rx: Receiver<ShardCmd>,
              results_tx: Sender<(usize, Result<ServeSummary>)>) {
    while let Ok(run) = run_rx.recv() {
        let registry = match run {
            ShardRun::Start { registry } => registry,
            ShardRun::Shutdown => break,
        };
        let outcome = serve(rt, &registry, &cfg.serve, log, |h| {
            while let Ok(cmd) = cmd_rx.recv() {
                match cmd {
                    ShardCmd::Submit { tenant, meta, input, reply } => {
                        let _ = reply.send(h.submit(&tenant, meta, input));
                    }
                    ShardCmd::Flush => h.flush(),
                    ShardCmd::Advance { dt_s } => h.advance_clock(dt_s),
                    ShardCmd::Tick { done } => {
                        h.tick();
                        let _ = done.send(());
                    }
                    ShardCmd::Stop => break,
                }
            }
            Ok(())
        });
        let _ = results_tx.send((shard, outcome.map(|o| o.summary)));
    }
}

/// Run a scoped sharded serving fleet: N shard lifecycle threads (each
/// hosting its own serve session, worker pool, registry, admission
/// ledger and state dir), with `body` driving traffic through the
/// [`ShardRouter`] on the caller's thread. When `body` returns, every
/// live session is stopped and drained, live shards with a store are
/// compacted, and all session summaries are returned.
pub fn serve_sharded<R, F>(rt: &Runtime, cfg: &ShardConfig, log: &EventLog,
                           body: F) -> Result<ShardOutcome<R>>
where
    F: FnOnce(&ShardRouter<'_>) -> Result<R>,
{
    if cfg.shards == 0 {
        bail!("a shard fleet needs at least one shard");
    }
    // fail before any thread or state dir exists, not per shard
    cfg.serve.policy.validate()?;
    let (results_tx, results_rx) = channel();
    std::thread::scope(|scope| {
        let mut seats = Vec::with_capacity(cfg.shards);
        for shard in 0..cfg.shards {
            let (cmd_tx, cmd_rx) = channel();
            let (run_tx, run_rx) = channel();
            let (registry, store, _recovered) =
                build_shard_registry(cfg, shard, log)?;
            let results_tx = results_tx.clone();
            scope.spawn(move || {
                shard_main(shard, rt, cfg, log, run_rx, cmd_rx, results_tx);
            });
            run_tx.send(ShardRun::Start { registry: registry.clone() })
                .ok().context("shard thread died at startup")?;
            seats.push(ShardSeat {
                cmd_tx,
                run_tx,
                registry: Mutex::new(Some(registry)),
                store: Mutex::new(store),
                alive: AtomicBool::new(true),
            });
        }
        let router = ShardRouter {
            cfg,
            log,
            table: RwLock::new(RoutingTable::new(cfg.shards)),
            seats,
            results_rx: Mutex::new(results_rx),
            collected: Mutex::new(Vec::new()),
            started: AtomicUsize::new(cfg.shards),
        };
        // a panicking body must not leave lifecycle threads parked on
        // their run channels (the scope would join forever): stop the
        // fleet first, then resume the panic
        let body_result = catch_unwind(AssertUnwindSafe(|| body(&router)));
        let shutdown_result = shutdown_fleet(&router);
        let body_value = match body_result {
            Ok(r) => r?,
            Err(p) => resume_unwind(p),
        };
        let sessions = shutdown_result?;
        Ok(ShardOutcome { body: body_value, sessions })
    })
}

/// Stop every live session, collect the remaining summaries, compact
/// live shards' stores, and release the lifecycle threads.
fn shutdown_fleet(router: &ShardRouter<'_>)
                  -> Result<Vec<(usize, ServeSummary)>> {
    let mut sessions = std::mem::take(&mut *lock_or_recover(&router.collected));
    let expected = router.started.load(Ordering::Acquire);
    // count *received* results, not successes: a failed session still
    // consumed its slot, and waiting for a replacement would block on
    // a channel that never closes
    let mut received = sessions.len();
    let mut first_err = None;
    {
        let rx = lock_or_recover(&router.results_rx);
        // stop live shards one at a time, waiting for each stopped
        // session's result before stopping the next: session-end
        // flight-recorder dumps (`serve_trace` lines) land in the
        // EventLog as one contiguous shard-ordered block instead of
        // interleaving across shards — part of the fifo byte-identity
        // contract
        for (shard, seat) in router.seats.iter().enumerate() {
            if !seat.alive.load(Ordering::Acquire) {
                continue;
            }
            if seat.cmd_tx.send(ShardCmd::Stop).is_err() {
                continue;
            }
            // wait for *this* shard's result, stashing any other
            // session that failed on its own in the meantime
            let mut done = false;
            while !done && received < expected {
                // analyze: allow(blocking-under-lock) shutdown is single-threaded by now; holding results_rx across recv keeps trace dumps shard-ordered
                let Ok((idx, res)) = rx.recv() else { break };
                received += 1;
                done = idx == shard;
                match res {
                    Ok(summary) => sessions.push((idx, summary)),
                    Err(e) => {
                        first_err.get_or_insert(
                            e.context(format!("shard {idx} serve session \
                                               failed")));
                    }
                }
            }
        }
        // drain stragglers: a session that failed before its Stop could
        // be sent still consumed a started slot
        while received < expected {
            // analyze: allow(blocking-under-lock) straggler drain at shutdown; see above
            let Ok((idx, res)) = rx.recv() else { break };
            received += 1;
            match res {
                Ok(summary) => sessions.push((idx, summary)),
                Err(e) => {
                    first_err.get_or_insert(
                        e.context(format!("shard {idx} serve session \
                                           failed")));
                }
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    // session-end compaction per live shard, mirroring the unsharded
    // bench: the next restart replays one snapshot instead of the WAL
    for (shard, seat) in router.seats.iter().enumerate() {
        // clone the Arcs inside a block so both seat guards are gone
        // before the (WAL-locking, fsyncing) compaction starts
        let snap = {
            let registry = lock_or_recover(&seat.registry).clone();
            let store = lock_or_recover(&seat.store).clone();
            registry.zip(store)
        };
        if let Some((registry, store)) = snap {
            registry.compact_into(&store)
                .with_context(|| format!("compact shard {shard} state"))?;
        }
    }
    for seat in &router.seats {
        let _ = seat.run_tx.send(ShardRun::Shutdown);
    }
    sessions.sort_by_key(|&(idx, _)| idx);
    Ok(sessions)
}

// --------------------------------------------------------- fleet summary ---

/// Per-shard and fleet-rollup metrics for a sharded bench run.
pub struct FleetSummary {
    pub shards: usize,
    /// (shard index, session summary), shard-ordered.
    pub sessions: Vec<(usize, ServeSummary)>,
}

impl FleetSummary {
    pub fn completed(&self) -> u64 {
        self.sessions.iter().map(|(_, s)| s.completed).sum()
    }

    pub fn failed(&self) -> u64 {
        self.sessions.iter().map(|(_, s)| s.failed).sum()
    }

    /// Fleet throughput: total completions over the longest session wall
    /// clock (sessions run concurrently).
    pub fn fleet_rps(&self) -> f64 {
        let wall = self.sessions.iter().map(|(_, s)| s.wall_s)
            .fold(0.0f64, f64::max);
        if wall > 0.0 { self.completed() as f64 / wall } else { 0.0 }
    }

    /// Worst p99 across shards — the fleet's tail is its slowest shard.
    /// `None` when no session completed a single request.
    pub fn p99_us(&self) -> Option<f64> {
        self.sessions.iter()
            .filter_map(|(_, s)| s.p99_us)
            .reduce(f64::max)
    }

    /// Fleet-wide SLO rollup: per-tenant request/violation counts merged
    /// across sessions by tenant name (a migrated or restarted tenant's
    /// traffic may span several sessions), under the shared policy.
    /// `None` when SLO tracking was off for the whole fleet.
    pub fn slo(&self) -> Option<SloSummary> {
        let first = self.sessions.iter().find_map(|(_, s)| s.slo.as_ref())?;
        let mut merged: BTreeMap<String, TenantSloStatus> = BTreeMap::new();
        for (_, s) in &self.sessions {
            let Some(slo) = &s.slo else { continue };
            for t in &slo.per_tenant {
                let e = merged.entry(t.tenant.clone()).or_insert_with(|| {
                    TenantSloStatus { tenant: t.tenant.clone(), requests: 0,
                                      violations: 0 }
                });
                e.requests += t.requests;
                e.violations += t.violations;
            }
        }
        Some(SloSummary {
            p99_target_us: first.p99_target_us,
            error_budget: first.error_budget,
            per_tenant: merged.into_values().collect(),
        })
    }

    pub fn emit(&self, log: &EventLog) {
        for (shard, s) in &self.sessions {
            log.emit("serve_shard", vec![
                ("shard", (*shard).into()),
                ("completed", Json::Num(s.completed as f64)),
                ("failed", Json::Num(s.failed as f64)),
                ("rps", Json::Num(s.rps)),
                ("p99_us", q_json(s.p99_us)),
            ]);
        }
        log.emit("serve_fleet", vec![
            ("shards", self.shards.into()),
            ("sessions", self.sessions.len().into()),
            ("completed", Json::Num(self.completed() as f64)),
            ("failed", Json::Num(self.failed() as f64)),
            ("fleet_rps", Json::Num(self.fleet_rps())),
            ("p99_us", q_json(self.p99_us())),
        ]);
    }

    /// Human-readable per-shard + fleet report for the CLI.
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        for (shard, sess) in &self.sessions {
            let _ = writeln!(
                s,
                "shard {shard:>3}: {:>8} served  {:>9.0} req/s  p50 \
                 {:>9}  p99 {:>9}  ({} failed)",
                sess.completed, sess.rps, q_us(sess.p50_us),
                q_us(sess.p99_us), sess.failed);
        }
        let _ = writeln!(
            s,
            "fleet ({} shards): {} served, {:.0} req/s, worst p99 \
             {}, {} failed",
            self.shards, self.completed(), self.fleet_rps(),
            q_us(self.p99_us()), self.failed());
        if let Some(slo) = self.slo() {
            let _ = writeln!(
                s,
                "fleet SLO: p99 target {:.1}µs, error budget {:.2}%",
                slo.p99_target_us, slo.error_budget * 100.0);
            for t in &slo.per_tenant {
                let _ = writeln!(
                    s,
                    "  {}: {} requests, {} violation(s), burn {:.2} {}",
                    t.tenant, t.requests, t.violations,
                    t.burn(slo.error_budget),
                    if t.compliant(slo.error_budget) { "[ok]" }
                    else { "[BREACHED]" });
            }
            let n = slo.per_tenant.len();
            let _ = writeln!(
                s,
                "fleet slo compliance: {}/{} tenant(s) within budget",
                n - slo.breached(), n);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_routing_is_deterministic_and_covers_all_shards() {
        let t = RoutingTable::new(4);
        let t2 = RoutingTable::new(4);
        let mut hit = [false; 4];
        for i in 0..256 {
            let name = format!("tenant{i:04}");
            let s = t.route(&name);
            assert_eq!(s, t2.route(&name), "routing must be pure");
            assert!(s < 4);
            hit[s] = true;
        }
        assert!(hit.iter().all(|&h| h), "256 tenants must touch every \
                                         shard: {hit:?}");
        // single shard: everything routes to it
        let one = RoutingTable::new(1);
        assert_eq!(one.route("anything"), 0);
    }

    #[test]
    fn consistent_hash_moves_few_tenants_when_fleet_grows() {
        let four = RoutingTable::new(4);
        let five = RoutingTable::new(5);
        let n = 1000;
        let moved = (0..n)
            .filter(|i| {
                let name = format!("tenant{i:04}");
                four.route(&name) != five.route(&name)
            })
            .count();
        // ideal consistent hashing moves ~1/5 of keys on 4 -> 5; allow
        // slack for vnode variance but far below the ~4/5 a mod-N hash
        // would reshuffle
        assert!(moved < n * 2 / 5, "moved {moved}/{n}");
        assert!(moved > 0, "growing the fleet must move someone");
    }

    #[test]
    fn overrides_take_precedence_and_flip_routing() {
        let mut t = RoutingTable::new(3);
        let home = t.route("acme");
        let away = (home + 1) % 3;
        t.overrides.insert("acme".to_string(), away);
        assert_eq!(t.route("acme"), away);
        // other tenants keep their ring placement
        assert_eq!(t.route("globex"), RoutingTable::new(3).route("globex"));
    }
}
