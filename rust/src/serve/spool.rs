//! Spool-directory adapter ingestion: hot upload without a registration
//! API. A watcher polls a directory for `QPCK` v2 adapter checkpoints,
//! validates each through the hardened [`checkpoint::load_adapter`] path
//! (via [`Registry::load_checkpoint`]), and hot-swaps it into the live
//! [`Registry`] — a dropped file becomes servable with no restart, and a
//! deleted file evicts its tenant.
//!
//! ## Protocol
//!
//! - **upload**: write the file elsewhere and atomically rename it into
//!   `spool/<name>.qpck` ([`checkpoint::save_adapter_atomic`] does this
//!   for you). As a second line of defense for non-atomic uploaders, a
//!   file is only ingested once its (size, mtime) is *stable across two
//!   consecutive polls*, so a write in progress is never read mid-way;
//! - **ingest** (atomic rename-after-read): the watcher first renames
//!   the candidate to a hidden staging name it owns (`.ingest.<name>`) —
//!   an atomic claim, so the bytes it validates cannot be swapped under
//!   it by a concurrent re-upload (that re-upload creates a new
//!   directory entry, picked up next poll) — then reads and validates,
//!   and only after the read renames the file back to its public name.
//!   Dot-files are invisible to the scanner, so a half-ingested file is
//!   never double-claimed;
//! - **reject**: a file that fails validation is quarantined to
//!   `spool/rejected/<name>` with the reason in the event log
//!   (`serve_spool_reject`) — it is never retried; a fixed upload under
//!   the same name is a fresh candidate. A *durable-log* failure
//!   ([`StateLogFailed`]) is not a rejection: the claim is restored and
//!   the ingest retried every window until the log recovers
//!   (`serve_spool_ingest_deferred`, logged once per episode);
//! - **delete**: removing `spool/<name>.qpck` evicts the tenant it
//!   loaded — *deferred* while the tenant has in-flight requests
//!   ([`Registry::try_evict_tenant`]) and retried every poll until the
//!   pins drain, so eviction never drops live work.
//!
//! Every ingest and eviction flows through the registry's durable
//! [`StateSink`](crate::store::StateSink) (when one is attached): an
//! upload or deletion observed by the spool survives a server restart.
//! A failed durable append defers the eviction (retried next poll)
//! rather than letting the in-RAM registry run ahead of its log.
//!
//! [`Spool`] is the synchronous poll-state machine (drive [`Spool::poll`]
//! directly in tests — no sleeps, fully deterministic);
//! [`SpoolWatcher`] runs it on a [`pool::Background`] thread whose
//! shutdown **joins** the poller, so a serve session can never leak its
//! watcher.
//!
//! [`checkpoint::load_adapter`]: crate::coordinator::checkpoint::load_adapter
//! [`checkpoint::save_adapter_atomic`]: crate::coordinator::checkpoint::save_adapter_atomic

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::{Duration, SystemTime};

use anyhow::{Context, Result};

use crate::coordinator::events::EventLog;
use crate::util::json::Json;
use crate::util::pool::Background;
use crate::util::sync::lock_or_recover;

use crate::store::StateLogFailed;

use super::registry::{EvictAttempt, Registry};

/// Quarantine subdirectory for files that failed validation.
pub const REJECTED_SUBDIR: &str = "rejected";

/// Where and how often to poll.
#[derive(Clone, Debug)]
pub struct SpoolConfig {
    pub dir: PathBuf,
    pub poll_interval: Duration,
}

impl SpoolConfig {
    pub fn new(dir: impl Into<PathBuf>) -> SpoolConfig {
        SpoolConfig { dir: dir.into(), poll_interval: Duration::from_millis(20) }
    }
}

/// Monotonic counters over a spool's lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpoolStats {
    pub polls: u64,
    /// Successful ingests (registrations + hot-swaps).
    pub loaded: u64,
    /// Files quarantined to `rejected/`.
    pub rejected: u64,
    /// Tenants evicted after their file was deleted.
    pub evicted: u64,
    /// Eviction attempts deferred on in-flight pins (one per poll).
    pub eviction_deferred: u64,
    /// Valid uploads whose durable log append failed — put back and
    /// retried (never quarantined for a log hiccup).
    pub ingest_deferred: u64,
}

enum Tracked {
    /// Seen once; ingested when unchanged on the next poll.
    /// `prev_tenant` carries the tenant a prior generation of this file
    /// loaded as, so a re-upload that switches tenants (or a deletion
    /// mid-window) can still orphan-evict the old one.
    Pending { len: u64, mtime: SystemTime, prev_tenant: Option<String> },
    /// Live in the registry, backed by this file state.
    Loaded { len: u64, mtime: SystemTime, tenant: String },
}

impl Tracked {
    fn tenant(&self) -> Option<&String> {
        match self {
            Tracked::Pending { prev_tenant, .. } => prev_tenant.as_ref(),
            Tracked::Loaded { tenant, .. } => Some(tenant),
        }
    }
}

enum Action {
    Skip,
    Track,
    Ingest,
}

/// The synchronous spool state machine: one [`poll`](Spool::poll) call
/// scans the directory once and converges the registry toward it.
pub struct Spool {
    registry: Arc<Registry>,
    dir: PathBuf,
    log: EventLog,
    /// File name -> what we know about it (public `*.qpck` names only).
    seen: BTreeMap<String, Tracked>,
    /// Tenants whose backing file is gone but whose eviction is blocked
    /// by in-flight pins; retried first thing every poll.
    pending_evictions: BTreeSet<String>,
    /// File names whose ingest hit a durable-log failure (logged once
    /// per episode; cleared on the next successful ingest).
    sink_deferred: BTreeSet<String>,
    stats: SpoolStats,
}

impl Spool {
    pub fn new(registry: Arc<Registry>, cfg: &SpoolConfig, log: EventLog)
               -> Result<Spool> {
        std::fs::create_dir_all(&cfg.dir)
            .with_context(|| format!("create spool dir {:?}", cfg.dir))?;
        Ok(Spool {
            registry,
            dir: cfg.dir.clone(),
            log,
            seen: BTreeMap::new(),
            pending_evictions: BTreeSet::new(),
            sink_deferred: BTreeSet::new(),
            stats: SpoolStats::default(),
        })
    }

    /// One full pass: retry deferred evictions, evict tenants whose files
    /// vanished, ingest stable new/changed files. Filesystem races
    /// (files vanishing between list and claim) degrade to "observe
    /// again next poll", never to a panic or a wedged watcher.
    pub fn poll(&mut self) -> SpoolStats {
        self.stats.polls += 1;
        let deferred: Vec<String> =
            self.pending_evictions.iter().cloned().collect();
        for tenant in deferred {
            self.pending_evictions.remove(&tenant);
            self.evict(tenant);
        }
        let listing = self.list();
        let gone: Vec<String> = self.seen.keys()
            .filter(|name| !listing.contains_key(*name))
            .cloned()
            .collect();
        for name in gone {
            if let Some(tenant) =
                self.seen.remove(&name).as_ref().and_then(Tracked::tenant)
            {
                let tenant = tenant.clone();
                self.evict(tenant);
            }
        }
        for (name, (len, mtime)) in listing {
            let action = match self.seen.get(&name) {
                Some(Tracked::Loaded { len: l, mtime: m, .. })
                    if *l == len && *m == mtime => Action::Skip,
                Some(Tracked::Pending { len: l, mtime: m, .. })
                    if *l == len && *m == mtime => Action::Ingest,
                // new file, or its bytes are still moving: (re)arm the
                // stability window, remembering any tenant a previous
                // generation of this file loaded as
                _ => Action::Track,
            };
            match action {
                Action::Skip => {}
                Action::Track => {
                    let prev_tenant =
                        self.seen.get(&name).and_then(Tracked::tenant).cloned();
                    self.seen.insert(
                        name,
                        Tracked::Pending { len, mtime, prev_tenant },
                    );
                }
                Action::Ingest => self.ingest(&name, len, mtime),
            }
        }
        self.stats
    }

    pub fn stats(&self) -> SpoolStats {
        self.stats
    }

    /// Public `*.qpck` entries of the spool dir (dot-files and the
    /// `rejected/` subdirectory are invisible).
    fn list(&self) -> BTreeMap<String, (u64, SystemTime)> {
        let mut out = BTreeMap::new();
        let Ok(rd) = std::fs::read_dir(&self.dir) else {
            return out;
        };
        for entry in rd.flatten() {
            let fname = entry.file_name();
            let Some(name) = fname.to_str() else {
                continue;
            };
            if name.starts_with('.') || !name.ends_with(".qpck") {
                continue;
            }
            let Ok(md) = entry.metadata() else {
                continue;
            };
            if !md.is_file() {
                continue;
            }
            let mtime = md.modified().unwrap_or(SystemTime::UNIX_EPOCH);
            out.insert(name.to_string(), (md.len(), mtime));
        }
        out
    }

    fn ingest(&mut self, name: &str, len: u64, mtime: SystemTime) {
        let public = self.dir.join(name);
        let staging = self.dir.join(format!(".ingest.{name}"));
        // atomic claim: from here no concurrent re-upload can change the
        // bytes we are about to validate
        if std::fs::rename(&public, &staging).is_err() {
            // vanished between listing and claim — re-observe next poll
            self.seen.remove(name);
            return;
        }
        match self.registry.load_checkpoint(&staging) {
            Ok((tenant, version)) => {
                self.sink_deferred.remove(name);
                // a tenant just (re)loaded from disk is no longer
                // eviction-pending, whatever an earlier deletion said
                self.pending_evictions.remove(&tenant);
                // the same file switching manifest tenants orphans the
                // old tenant: its backing file is gone now
                let prev = self.seen.get(name).and_then(Tracked::tenant).cloned();
                if let Some(old) = prev {
                    if old != tenant {
                        self.evict(old);
                    }
                }
                self.stats.loaded += 1;
                self.log.emit("serve_spool_load", vec![
                    ("file", name.into()),
                    ("tenant", tenant.as_str().into()),
                    ("version", Json::Num(version as f64)),
                ]);
                if std::fs::rename(&staging, &public).is_ok() {
                    self.seen.insert(
                        name.to_string(),
                        Tracked::Loaded { len, mtime, tenant },
                    );
                } else {
                    // could not restore the public name: treat the file
                    // as deleted so the tenant cannot outlive a file
                    // that is not there
                    self.log.emit("serve_spool_error", vec![
                        ("file", name.into()),
                        ("error", "failed to restore ingested file".into()),
                    ]);
                    self.seen.remove(name);
                    self.evict(tenant);
                }
            }
            // a failed durable-log append is NOT a bad upload: put the
            // claim back under its public name and retry next window —
            // quarantining a valid adapter over a log-disk hiccup would
            // lose the upload permanently
            Err(e) if e.downcast_ref::<StateLogFailed>().is_some() => {
                self.stats.ingest_deferred += 1;
                let restored = std::fs::rename(&staging, &public).is_ok();
                if self.sink_deferred.insert(name.to_string()) || !restored {
                    self.log.emit("serve_spool_ingest_deferred", vec![
                        ("file", name.into()),
                        ("restored", restored.to_string().into()),
                        ("error", e.to_string().into()),
                    ]);
                }
                // forget the window state either way: a restored file is
                // re-observed (and retried) next poll; an unrestorable
                // one is effectively gone
                self.seen.remove(name);
            }
            Err(e) => {
                self.stats.rejected += 1;
                // a quarantine ends any sink-deferral episode for this
                // name: a future genuine log outage must log afresh
                self.sink_deferred.remove(name);
                let dest = self.quarantine_dest(name);
                let moved = std::fs::create_dir_all(self.dir.join(REJECTED_SUBDIR))
                    .and_then(|()| std::fs::rename(&staging, &dest));
                self.log.emit("serve_spool_reject", vec![
                    ("file", name.into()),
                    ("quarantined", moved.is_ok().to_string().into()),
                    ("error", e.to_string().into()),
                ]);
                // whether or not the quarantine rename worked, the public
                // name is gone: nothing left to retry forever
                self.seen.remove(name);
            }
        }
    }

    fn quarantine_dest(&self, name: &str) -> PathBuf {
        let base = self.dir.join(REJECTED_SUBDIR);
        let mut dest = base.join(name);
        let mut k = 1;
        while dest.exists() {
            k += 1;
            dest = base.join(format!("{name}.{k}"));
        }
        dest
    }

    /// Evict now if possible; defer (and retry every poll) on in-flight
    /// pins or on a failed durable-eviction append (the registry keeps
    /// the tenant live when its WAL record cannot be written — RAM must
    /// never run ahead of the log).
    fn evict(&mut self, tenant: String) {
        match self.registry.try_evict_tenant(&tenant) {
            Ok(EvictAttempt::Evicted) => {
                self.stats.evicted += 1;
                self.log.emit("serve_spool_evict", vec![
                    ("tenant", tenant.as_str().into()),
                ]);
            }
            Ok(EvictAttempt::Unknown) => {}
            Ok(EvictAttempt::Deferred(inflight)) => {
                self.stats.eviction_deferred += 1;
                if self.pending_evictions.insert(tenant.clone()) {
                    self.log.emit("serve_spool_evict_deferred", vec![
                        ("tenant", tenant.as_str().into()),
                        ("inflight", inflight.into()),
                    ]);
                }
            }
            Err(e) => {
                self.stats.eviction_deferred += 1;
                // log on first deferral only (like the Deferred arm): a
                // persistently failing sink must not flood the event
                // log once per poll interval
                if self.pending_evictions.insert(tenant.clone()) {
                    self.log.emit("serve_spool_error", vec![
                        ("tenant", tenant.as_str().into()),
                        ("error", e.to_string().into()),
                    ]);
                }
            }
        }
    }
}

/// Stability-window watcher for **one** file — the spool's
/// (len, mtime)-stable-across-two-polls technique applied to a single
/// path (used by the admission-config hot-reload,
/// [`crate::serve::admission::AdmissionReload`]). [`poll`](FileWatch::poll)
/// returns the file's contents exactly once per new stable version;
/// a write in progress is never read half-way. Drive `poll` directly in
/// tests (no clock, fully deterministic) or from a
/// [`Background`] thread in production.
pub struct FileWatch {
    path: PathBuf,
    /// Seen once; reported when unchanged on the next poll.
    pending: Option<(u64, SystemTime)>,
    /// The version already reported.
    loaded: Option<(u64, SystemTime)>,
}

impl FileWatch {
    pub fn new(path: impl Into<PathBuf>) -> FileWatch {
        FileWatch { path: path.into(), pending: None, loaded: None }
    }

    /// A watcher that treats `already_loaded` — a (len, mtime)
    /// signature the caller observed when it consumed the file itself —
    /// as the reported version: [`poll`](FileWatch::poll) fires only
    /// when the file *changes from that signature*. The hot-reload
    /// startup case: the session was configured from the file (possibly
    /// with CLI overrides on top), so re-applying the unchanged file
    /// would revert the overrides, while an edit that raced session
    /// startup must still be detected — which is why the caller records
    /// the signature at read time rather than this watcher stat-ing the
    /// (possibly already-edited) file later.
    pub fn starting_from(path: impl Into<PathBuf>,
                         already_loaded: Option<(u64, SystemTime)>)
                         -> FileWatch {
        FileWatch { path: path.into(), pending: None, loaded: already_loaded }
    }

    /// [`starting_from`](FileWatch::starting_from) with the signature
    /// observed right now (callers that read the file at the same
    /// moment; prefer recording the signature at read time when the
    /// read happened earlier).
    pub fn starting_from_current(path: impl Into<PathBuf>) -> FileWatch {
        let mut w = FileWatch::new(path);
        if let Ok(md) = std::fs::metadata(&w.path) {
            if md.is_file() {
                w.loaded = Some((
                    md.len(),
                    md.modified().unwrap_or(SystemTime::UNIX_EPOCH),
                ));
            }
        }
        w
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }

    /// One poll: `Some(contents)` the first time a new (len, mtime)
    /// signature has been stable across two consecutive polls; `None`
    /// otherwise (missing file, still-moving bytes, already reported).
    pub fn poll(&mut self) -> Option<Vec<u8>> {
        let Ok(md) = std::fs::metadata(&self.path) else {
            self.pending = None;
            return None;
        };
        if !md.is_file() {
            self.pending = None;
            return None;
        }
        let sig = (md.len(), md.modified().unwrap_or(SystemTime::UNIX_EPOCH));
        if Some(sig) == self.loaded {
            return None;
        }
        if Some(sig) == self.pending {
            match std::fs::read(&self.path) {
                Ok(bytes) => {
                    self.loaded = Some(sig);
                    self.pending = None;
                    Some(bytes)
                }
                // vanished between stat and read: observe again next poll
                Err(_) => {
                    self.pending = None;
                    None
                }
            }
        } else {
            self.pending = Some(sig);
            None
        }
    }
}

/// A [`Spool`] driven by a [`Background`] poller thread. Shutdown —
/// explicit [`shutdown`](SpoolWatcher::shutdown) or drop — stops the
/// thread and joins it.
pub struct SpoolWatcher {
    stats: Arc<Mutex<SpoolStats>>,
    bg: Background,
}

impl SpoolWatcher {
    pub fn start(registry: Arc<Registry>, cfg: SpoolConfig, log: EventLog)
                 -> Result<SpoolWatcher> {
        let mut spool = Spool::new(registry, &cfg, log)?;
        let stats = Arc::new(Mutex::new(SpoolStats::default()));
        let tick_stats = stats.clone();
        let bg = Background::spawn("spool-watcher", cfg.poll_interval, move || {
            *lock_or_recover(&tick_stats) = spool.poll();
        })
        .context("spawn spool watcher thread")?;
        Ok(SpoolWatcher { stats, bg })
    }

    /// Counters as of the most recent completed poll.
    pub fn stats(&self) -> SpoolStats {
        *lock_or_recover(&self.stats)
    }

    /// Stop polling and join the watcher thread (dropping the watcher
    /// does the same).
    pub fn shutdown(self) {
        self.bg.stop_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::checkpoint::{save_adapter_atomic, AdapterManifest};
    use crate::runtime::HostTensor;
    use crate::serve::registry::PauliSpec;
    use std::path::Path;

    fn tdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("qp_spool_unit")
            .join(format!("{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn drop_adapter(dir: &Path, file: &str, tenant: &str, q: u32, l: u32) {
        let spec = PauliSpec { q, n_layers: l };
        let thetas: Vec<f32> = (0..spec.num_params())
            .map(|i| (i as f32 * 0.19).sin())
            .collect();
        let m = AdapterManifest { tenant: tenant.into(), q, n_layers: l };
        save_adapter_atomic(&dir.join(file), &m, &[(
            "thetas".to_string(),
            HostTensor::f32(vec![thetas.len()], thetas),
        )])
        .unwrap();
    }

    #[test]
    fn stability_window_defers_ingest_one_poll() {
        let dir = tdir("stable");
        let reg = Arc::new(Registry::new(1 << 20));
        let mut spool =
            Spool::new(reg.clone(), &SpoolConfig::new(&dir), EventLog::null())
                .unwrap();
        drop_adapter(&dir, "a.qpck", "acme", 3, 1);
        // first sighting only arms the window
        let s = spool.poll();
        assert_eq!(s.loaded, 0);
        assert!(reg.snapshot("acme").is_err());
        // unchanged on the second poll -> ingested
        let s = spool.poll();
        assert_eq!(s.loaded, 1);
        assert_eq!(reg.snapshot("acme").unwrap().version, 1);
        // steady state: no re-ingest
        let s = spool.poll();
        assert_eq!(s.loaded, 1);
        assert_eq!(reg.snapshot("acme").unwrap().version, 1);
    }

    #[test]
    fn changed_file_hot_swaps_and_tenant_rename_evicts_the_old() {
        let dir = tdir("swap");
        let reg = Arc::new(Registry::new(1 << 20));
        let mut spool =
            Spool::new(reg.clone(), &SpoolConfig::new(&dir), EventLog::null())
                .unwrap();
        drop_adapter(&dir, "a.qpck", "acme", 3, 1);
        spool.poll();
        spool.poll();
        let v1 = reg.snapshot("acme").unwrap();
        // re-upload under the same file name: hot-swap bumps the version
        // (different shape -> different bytes, so (len, mtime) changes)
        drop_adapter(&dir, "a.qpck", "acme", 3, 2);
        spool.poll();
        spool.poll();
        let v2 = reg.snapshot("acme").unwrap();
        assert_eq!((v1.version, v2.version), (1, 2));
        assert_ne!(v1.checksum, v2.checksum);
        // the same file switching manifest tenants orphans the old one
        drop_adapter(&dir, "a.qpck", "globex", 3, 1);
        spool.poll();
        spool.poll();
        assert!(reg.snapshot("acme").is_err(), "orphaned tenant survived");
        assert_eq!(reg.snapshot("globex").unwrap().version, 1);
    }

    #[test]
    fn sink_failure_defers_ingest_instead_of_quarantining() {
        use crate::store::{StateRecord, StateSink};
        use std::sync::atomic::{AtomicBool, Ordering};

        struct FlakySink {
            down: AtomicBool,
        }
        impl StateSink for FlakySink {
            fn record(&self, _rec: &StateRecord) -> anyhow::Result<()> {
                if self.down.load(Ordering::Relaxed) {
                    anyhow::bail!("log disk full");
                }
                Ok(())
            }
        }

        let dir = tdir("sink_defer");
        let sink = Arc::new(FlakySink { down: AtomicBool::new(true) });
        let reg = Arc::new(
            Registry::new(1 << 20).with_state_sink(sink.clone()));
        let mut spool =
            Spool::new(reg.clone(), &SpoolConfig::new(&dir), EventLog::null())
                .unwrap();
        drop_adapter(&dir, "a.qpck", "acme", 3, 1);
        spool.poll();
        let s = spool.poll(); // ingest attempt hits the failing sink
        assert_eq!((s.loaded, s.rejected), (0, 0), "{s:?}");
        assert!(s.ingest_deferred >= 1, "{s:?}");
        assert!(reg.is_empty());
        // the upload was NOT quarantined: it is back under its public
        // name, and once the log recovers the retry ingests it
        assert!(dir.join("a.qpck").exists(), "valid upload was lost");
        assert!(!dir.join("rejected").join("a.qpck").exists());
        sink.down.store(false, Ordering::Relaxed);
        spool.poll(); // re-observe (stability window re-arms)
        let s = spool.poll(); // retry succeeds
        assert_eq!((s.loaded, s.rejected), (1, 0), "{s:?}");
        assert_eq!(reg.snapshot("acme").unwrap().version, 1);
    }

    #[test]
    fn file_watch_reports_each_stable_version_once() {
        let dir = tdir("fwatch");
        let path = dir.join("cfg.json");
        let mut w = FileWatch::new(&path);
        // missing file: silent
        assert!(w.poll().is_none());
        std::fs::write(&path, b"v1").unwrap();
        // first sighting arms the window, second reports, third is quiet
        assert!(w.poll().is_none());
        assert_eq!(w.poll().as_deref(), Some(b"v1".as_slice()));
        assert!(w.poll().is_none());
        // a rewrite goes through the same window
        std::fs::write(&path, b"version-two").unwrap();
        assert!(w.poll().is_none());
        assert_eq!(w.poll().as_deref(), Some(b"version-two".as_slice()));
        assert!(w.poll().is_none());
        // deletion is silent and re-arms for the next upload
        std::fs::remove_file(&path).unwrap();
        assert!(w.poll().is_none());
        // a different length than any earlier version, so the (len,
        // mtime) signature changes even on coarse-mtime filesystems
        std::fs::write(&path, b"v3-value").unwrap();
        assert!(w.poll().is_none());
        assert_eq!(w.poll().as_deref(), Some(b"v3-value".as_slice()));
        // starting_from_current: the existing version is pre-loaded and
        // never reported; only a subsequent edit fires
        let mut pre = FileWatch::starting_from_current(&path);
        for _ in 0..3 {
            assert!(pre.poll().is_none(), "unchanged file re-reported");
        }
        std::fs::write(&path, b"edited-after-start").unwrap();
        assert!(pre.poll().is_none());
        assert_eq!(pre.poll().as_deref(), Some(b"edited-after-start".as_slice()));
    }

    #[test]
    fn non_qpck_and_dot_files_are_ignored() {
        let dir = tdir("ignore");
        let reg = Arc::new(Registry::new(1 << 20));
        let mut spool =
            Spool::new(reg.clone(), &SpoolConfig::new(&dir), EventLog::null())
                .unwrap();
        std::fs::write(dir.join("notes.txt"), b"not an adapter").unwrap();
        std::fs::write(dir.join(".hidden.qpck"), b"partial upload").unwrap();
        std::fs::create_dir_all(dir.join("rejected")).unwrap();
        for _ in 0..3 {
            spool.poll();
        }
        let s = spool.stats();
        assert_eq!((s.loaded, s.rejected), (0, 0), "{s:?}");
        assert!(reg.is_empty());
    }
}
