//! Durable serving state store: write-ahead log + snapshots + crash
//! recovery for the adapter registry's control-plane state.
//!
//! The paper's log-scale Pauli adapters make thousands of per-tenant
//! fine-tunes cheap to *hold* in RAM — which means a serve-plane restart
//! used to lose every ingested tenant, version counter and eviction.
//! This subsystem makes registry **mutations** durable, so a restarted
//! server serves the same tenants at the same versions with
//! byte-identical responses:
//!
//! - [`wal`]: an append-only record log of registry mutations
//!   (register / swap / evict, each carrying tenant, version, theta
//!   checksum, originating `QPCK` path and the theta payload itself).
//!   Records are length-prefixed and CRC32-framed; fsync cadence sits
//!   behind the [`Durability`] knob;
//! - [`snapshot`]: periodic compaction — the live registry state is
//!   written to a single checksummed snapshot file via temp-file +
//!   atomic same-directory rename, then the WAL is truncated, so
//!   recovery cost stays proportional to the live tenant count, not the
//!   mutation history;
//! - [`mod@recover`]: startup replay — load the snapshot (if any), then
//!   apply the WAL tail, skipping records the snapshot already covers
//!   (every record carries a sequence number; the snapshot pins the last
//!   one it includes). Exactly one **torn trailing record** — the
//!   fingerprint of a crash mid-append — is tolerated and truncated
//!   away; anything worse (a CRC mismatch with complete records after
//!   it, a non-monotonic sequence, an undecodable record) is a typed
//!   [`CorruptState`] error, never a silent partial load.
//!
//! ## What is durable, and when
//!
//! A mutation is durable once its WAL record is on disk: the registry
//! appends the record *before* applying the mutation in RAM (classic
//! write-ahead discipline, see
//! [`Registry::with_state_sink`](crate::serve::registry::Registry::with_state_sink)),
//! so a crash can lose at most the in-RAM effect of a record that will
//! be replayed — never a mutation that was acknowledged. How hard
//! "on disk" is depends on [`Durability`]: `Buffered` leaves it to the
//! OS page cache (a *process* crash loses nothing, a power cut may lose
//! the tail), `EveryN(n)` bounds the loss window to n records, `Always`
//! fsyncs every append. Snapshots and WAL truncations are always
//! fsynced — compaction never weakens what the WAL had already made
//! durable.
//!
//! The store knows nothing about the serving layer: it logs and
//! recovers [`TenantState`] values. The registry side of the contract
//! lives in [`crate::serve::registry`] (the [`StateSink`] emission and
//! [`Registry::restore`](crate::serve::registry::Registry::restore)).

pub mod recover;
pub mod snapshot;
pub mod wal;

use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::obs::hist::Hist;
use crate::obs::metrics::{detached_hist, Class, Counter, MetricsRegistry};
use crate::obs::span::SpanClock;
use crate::util::sync::{lock_observed, lock_or_recover, LockObs};

pub use recover::{recover, RecoveredState};
pub use snapshot::SNAPSHOT_FILE;
pub use wal::{Durability, WalObs, WalWriter, WAL_FILE};

/// One tenant's complete durable state: everything recovery needs to
/// re-register the tenant at the same version with the same parameters
/// (the thetas ride along — they are few-KB by the paper's eq. 2, so
/// the *metadata churn*, not the bytes, dominates the log).
#[derive(Clone, Debug, PartialEq)]
pub struct TenantState {
    pub tenant: String,
    pub version: u64,
    pub q: u32,
    pub n_layers: u32,
    /// FNV-1a digest of the theta bits (the registry's adapter identity
    /// digest); recovery re-verifies it against `thetas`.
    pub checksum: u64,
    /// Originating `QPCK` checkpoint path ("" for programmatic
    /// registrations) — diagnostic provenance, not a load dependency.
    pub path: String,
    pub thetas: Vec<f32>,
}

/// One registry mutation, as logged. `Register` is a tenant's first
/// version, `Swap` a hot-swap of an existing tenant; both carry the full
/// [`TenantState`] and replay identically.
#[derive(Clone, Debug, PartialEq)]
pub enum StateRecord {
    Register(TenantState),
    Swap(TenantState),
    Evict { tenant: String },
}

impl StateRecord {
    /// The tenant this record mutates.
    pub fn tenant(&self) -> &str {
        match self {
            StateRecord::Register(ts) | StateRecord::Swap(ts) => &ts.tenant,
            StateRecord::Evict { tenant } => tenant,
        }
    }
}

/// Where the registry sends its mutation records. The serving layer is
/// generic over this: [`NullSink`] (the default) preserves the purely
/// in-RAM behavior byte-for-byte; [`StateStore`] makes mutations
/// durable. An `Err` from [`record`](StateSink::record) aborts the
/// mutation *before* it is applied in RAM (write-ahead discipline).
pub trait StateSink: Send + Sync {
    fn record(&self, rec: &StateRecord) -> Result<()>;

    /// Whether this sink wants records at all. The registry checks it
    /// before *building* a record — constructing one clones the full
    /// theta vector, and the default [`NullSink`] configuration must
    /// stay byte- and allocation-identical to the pre-durability
    /// registry. Defaults to `true`.
    fn wants_records(&self) -> bool {
        true
    }
}

/// The no-op sink: accepts every record, persists nothing.
#[derive(Clone, Copy, Debug, Default)]
pub struct NullSink;

impl StateSink for NullSink {
    fn record(&self, _rec: &StateRecord) -> Result<()> {
        Ok(())
    }

    fn wants_records(&self) -> bool {
        false
    }
}

/// Typed corruption error: the state directory holds something neither
/// a clean log nor a single torn trailing record can explain. Carried
/// through `anyhow` as a payload, so callers can
/// `err.downcast_ref::<CorruptState>()` however much context wraps it
/// (the same recoverable-typed-error pattern as
/// [`crate::serve::admission::Rejected`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CorruptState {
    /// The offending file (WAL or snapshot), as a display path.
    pub file: String,
    /// Byte offset of the first bad frame (0 for whole-file problems).
    pub offset: u64,
    pub detail: String,
}

impl fmt::Display for CorruptState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "corrupt state file {} at offset {}: {}",
            self.file, self.offset, self.detail
        )
    }
}

impl std::error::Error for CorruptState {}

/// Typed marker for a failed durable append: the [`StateSink`] could
/// not log a mutation, so the mutation was aborted *before* applying
/// (write-ahead discipline) and the caller may safely retry. Carried as
/// an `anyhow` payload so callers can `downcast_ref` it apart from
/// permanent validation failures — the spool uses this to defer-and-
/// retry an ingest or eviction instead of quarantining a valid upload
/// because the log disk hiccuped.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StateLogFailed {
    pub tenant: String,
    pub detail: String,
}

impl fmt::Display for StateLogFailed {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "durable state log append failed for tenant {:?}: {}",
            self.tenant, self.detail
        )
    }
}

impl std::error::Error for StateLogFailed {}

/// A [`StateStore`] freshly opened on a state directory, plus whatever
/// [`recover()`] reconstructed from it (empty on a first run).
pub struct OpenedStore {
    pub store: StateStore,
    pub recovered: RecoveredState,
}

/// Store-level metric handles: append count/latency, compaction
/// count/latency, recovery replay stats, and the `store_wal` lock site.
/// Append and recovery *counts* are [`Class::Stable`] (pure functions
/// of the mutation stream and the on-disk state); every duration is
/// [`Class::Volatile`]. Defaults to detached ([`StoreObs::disabled`]) —
/// [`StateStore::instrument`] installs live handles.
#[derive(Clone, Debug)]
pub struct StoreObs {
    clock: Arc<SpanClock>,
    wal_lock: LockObs,
    appends: Arc<Counter>,
    append_ns: Arc<Hist>,
    snapshot_writes: Arc<Counter>,
    snapshot_ns: Arc<Hist>,
    recovered_records: Arc<Counter>,
    recovered_tenants: Arc<Counter>,
    torn_tails: Arc<Counter>,
}

impl StoreObs {
    /// Register the store metrics on `reg`. Re-registering returns
    /// handles onto the same metrics (shards sharing a registry sum).
    pub fn register(reg: &MetricsRegistry) -> StoreObs {
        StoreObs {
            clock: reg.clock(),
            wal_lock: LockObs::register(reg, "store_wal"),
            appends: reg.counter("wal_appends_total", &[], Class::Stable),
            append_ns: reg.hist("wal_append_ns", &[], Class::Volatile),
            snapshot_writes: reg
                .counter("wal_snapshot_writes_total", &[], Class::Stable),
            snapshot_ns: reg.hist("wal_snapshot_write_ns", &[], Class::Volatile),
            recovered_records: reg
                .counter("wal_recovered_records_total", &[], Class::Stable),
            recovered_tenants: reg
                .counter("wal_recovered_tenants_total", &[], Class::Stable),
            torn_tails: reg.counter("wal_torn_tails_total", &[], Class::Stable),
        }
    }

    /// Detached handles: the store runs identically, nothing exports.
    pub fn disabled() -> StoreObs {
        StoreObs {
            clock: Arc::new(SpanClock::new(true)),
            wal_lock: LockObs::disabled(),
            appends: Counter::detached(),
            append_ns: detached_hist(),
            snapshot_writes: Counter::detached(),
            snapshot_ns: detached_hist(),
            recovered_records: Counter::detached(),
            recovered_tenants: Counter::detached(),
            torn_tails: Counter::detached(),
        }
    }

    /// Credit a finished recovery to the replay counters.
    pub fn note_recovery(&self, recovered: &RecoveredState) {
        self.recovered_records.add(recovered.wal_records);
        self.recovered_tenants
            .add(recovered.tenants.len() as u64);
        if recovered.torn_tail {
            self.torn_tails.inc();
        }
    }

    pub fn appends(&self) -> u64 {
        self.appends.get()
    }

    pub fn snapshot_writes(&self) -> u64 {
        self.snapshot_writes.get()
    }

    pub fn recovered_tenants(&self) -> u64 {
        self.recovered_tenants.get()
    }
}

/// The open, writable state store: a [`WalWriter`] behind a mutex (so
/// any number of registry threads can append; order is the mutex's
/// order, which the registry makes coincide with mutation order by
/// appending under its own write lock) plus the directory the snapshot
/// compactions go to.
pub struct StateStore {
    dir: PathBuf,
    wal: Mutex<WalWriter>,
    obs: StoreObs,
}

impl StateStore {
    /// Open-or-recover: create `dir` if needed, replay snapshot + WAL
    /// (see [`recover()`]), truncate away a torn trailing record if one
    /// exists, and position the log for appending. The recovered tenant
    /// states come back alongside the store so the caller can restore
    /// them into a registry *before* attaching the store as its sink.
    pub fn open(dir: &Path, durability: Durability) -> Result<OpenedStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create state dir {dir:?}"))?;
        let recovered = recover::recover(dir)?;
        let wal = WalWriter::open(
            &dir.join(WAL_FILE),
            recovered.wal_valid_len,
            recovered.last_seq + 1,
            durability,
        )?;
        Ok(OpenedStore {
            store: StateStore {
                dir: dir.to_path_buf(),
                wal: Mutex::new(wal),
                obs: StoreObs::disabled(),
            },
            recovered,
        })
    }

    /// Attach metric handles to this store (and its WAL writer) and
    /// credit `recovered` to the replay counters. Call once, while the
    /// store is still exclusively owned — before it is shared as a
    /// [`StateSink`].
    pub fn instrument(&mut self, reg: &MetricsRegistry,
                      recovered: &RecoveredState) {
        self.obs = StoreObs::register(reg);
        self.obs.note_recovery(recovered);
        lock_or_recover(&self.wal).set_obs(WalObs::register(reg));
    }

    /// The store's metric handles (detached until
    /// [`StateStore::instrument`] installs live ones).
    pub fn obs(&self) -> &StoreObs {
        &self.obs
    }

    /// Append one mutation record; returns its sequence number. Durable
    /// per the store's [`Durability`] once this returns.
    pub fn append(&self, rec: &StateRecord) -> Result<u64> {
        let start = self.obs.clock.now_ns();
        let seq = lock_observed(&self.obs.wal_lock, &self.wal).append(rec)?;
        self.obs
            .append_ns
            .record(self.obs.clock.now_ns().saturating_sub(start));
        self.obs.appends.inc();
        Ok(seq)
    }

    /// Compact: write `live` (the complete current registry state) as
    /// an atomic-rename snapshot pinned to the last appended sequence
    /// number, then truncate the WAL. `live` must include the effect of
    /// every record appended so far — callers must quiesce mutations
    /// for the call (the registry integration,
    /// [`Registry::compact_into`](crate::serve::registry::Registry::compact_into),
    /// holds the registry write lock to guarantee it).
    pub fn compact(&self, live: &[TenantState]) -> Result<()> {
        let start = self.obs.clock.now_ns();
        {
            let mut wal = lock_observed(&self.obs.wal_lock, &self.wal);
            // analyze: allow(blocking-under-lock) deliberate: snapshot + truncate must be atomic w.r.t. appends, see the doc comment above
            snapshot::write(&self.dir, wal.last_seq(), live)
                .with_context(|| format!("write snapshot in {:?}", self.dir))?;
            // analyze: allow(blocking-under-lock) deliberate: see above — truncating outside the lock could drop a concurrent append
            wal.truncate_to_header()
                .context("truncate WAL after snapshot")?;
        }
        self.obs
            .snapshot_ns
            .record(self.obs.clock.now_ns().saturating_sub(start));
        self.obs.snapshot_writes.inc();
        Ok(())
    }

    /// Force the WAL to disk now, whatever the durability mode.
    pub fn sync(&self) -> Result<()> {
        lock_observed(&self.obs.wal_lock, &self.wal).sync()
    }

    /// Sequence number of the most recently appended record (0 if none
    /// were ever appended to this log line).
    pub fn last_seq(&self) -> u64 {
        lock_or_recover(&self.wal).last_seq()
    }

    /// Records appended since open or the last compaction — what a
    /// recovery would have to replay right now.
    pub fn wal_records(&self) -> u64 {
        lock_or_recover(&self.wal).records_since_truncate()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

impl StateSink for StateStore {
    fn record(&self, rec: &StateRecord) -> Result<()> {
        self.append(rec).map(|_seq| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join("qp_store_unit")
            .join(format!("{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn ts(tenant: &str, version: u64, fill: f32) -> TenantState {
        let thetas = vec![fill; 9];
        TenantState {
            tenant: tenant.to_string(),
            version,
            q: 3,
            n_layers: 1,
            checksum: crate::serve::registry::theta_checksum(&thetas),
            path: format!("/spool/{tenant}.qpck"),
            thetas,
        }
    }

    #[test]
    fn open_append_reopen_recovers_exact_state() {
        let dir = tdir("roundtrip");
        let opened = StateStore::open(&dir, Durability::Buffered).unwrap();
        assert!(opened.recovered.tenants.is_empty());
        let store = opened.store;
        store.append(&StateRecord::Register(ts("a", 1, 0.1))).unwrap();
        store.append(&StateRecord::Register(ts("b", 1, 0.2))).unwrap();
        store.append(&StateRecord::Swap(ts("a", 2, 0.3))).unwrap();
        store.append(&StateRecord::Evict { tenant: "b".into() }).unwrap();
        assert_eq!(store.last_seq(), 4);
        drop(store);
        let opened = StateStore::open(&dir, Durability::Buffered).unwrap();
        let r = &opened.recovered;
        assert_eq!(r.last_seq, 4);
        assert!(!r.torn_tail);
        assert_eq!(r.wal_records, 4);
        assert_eq!(r.tenants, vec![ts("a", 2, 0.3)]);
        // appends continue the sequence, never reuse it
        assert_eq!(
            opened.store.append(&StateRecord::Register(ts("c", 1, 0.4))).unwrap(),
            5
        );
    }

    #[test]
    fn compact_bounds_replay_and_preserves_state() {
        let dir = tdir("compact");
        let store = StateStore::open(&dir, Durability::Buffered).unwrap().store;
        for i in 0..8u64 {
            store
                .append(&StateRecord::Swap(ts("t", i + 1, i as f32)))
                .unwrap();
        }
        store.compact(&[ts("t", 8, 7.0)]).unwrap();
        assert_eq!(store.wal_records(), 0);
        // post-compaction mutations land in the fresh WAL tail
        store.append(&StateRecord::Register(ts("u", 1, 0.5))).unwrap();
        let opened = StateStore::open(&dir, Durability::Buffered).unwrap();
        let r = &opened.recovered;
        assert_eq!(r.snapshot_entries, 1);
        assert_eq!(r.wal_records, 1);
        assert_eq!(r.last_seq, 9);
        assert_eq!(r.tenants, vec![ts("t", 8, 7.0), ts("u", 1, 0.5)]);
    }

    #[test]
    fn instrumented_store_counts_appends_fsyncs_and_recovery() {
        let dir = tdir("obs");
        let reg = MetricsRegistry::new(false);
        let opened = StateStore::open(&dir, Durability::Always).unwrap();
        let mut store = opened.store;
        store.instrument(&reg, &opened.recovered);
        store.append(&StateRecord::Register(ts("a", 1, 0.1))).unwrap();
        store.append(&StateRecord::Swap(ts("a", 2, 0.2))).unwrap();
        store.compact(&[ts("a", 2, 0.2)]).unwrap();
        assert_eq!(store.obs().appends(), 2);
        assert_eq!(store.obs().snapshot_writes(), 1);
        // Always durability: one fsync per append, plus the truncation
        let wal_obs = WalObs::register(&reg);
        assert_eq!(wal_obs.fsyncs(), 3);
        assert!(wal_obs.append_bytes() > 0);
        // a fresh open over the snapshot replays one tenant, no records
        drop(store);
        let opened = StateStore::open(&dir, Durability::Buffered).unwrap();
        let mut store = opened.store;
        store.instrument(&reg, &opened.recovered);
        assert_eq!(store.obs().recovered_tenants(), 1);
    }

    #[test]
    fn null_sink_accepts_everything() {
        NullSink.record(&StateRecord::Evict { tenant: "x".into() }).unwrap();
    }

    #[test]
    fn corrupt_state_displays_and_downcasts() {
        fn fail() -> Result<()> {
            Err(CorruptState {
                file: "wal.log".into(),
                offset: 42,
                detail: "CRC mismatch".into(),
            })?;
            Ok(())
        }
        let e = fail().context("recovering").unwrap_err();
        assert!(e.to_string().contains("offset 42"), "{e}");
        let c = e.downcast_ref::<CorruptState>().expect("typed corruption lost");
        assert_eq!(c.offset, 42);
    }
}
