//! Startup recovery: snapshot first, then the WAL tail.
//!
//! The replay contract, which `tests/store.rs` pins with a truncate-
//! everywhere crash-injection matrix:
//!
//! - the reconstructed state is exactly the state after the **last
//!   complete record** — a crash mid-append loses that append and
//!   nothing else;
//! - exactly one *torn trailing record* is tolerated (a frame that runs
//!   past EOF, or a CRC-failed frame that is the last thing in the
//!   file — both are what a single interrupted `write_all` leaves
//!   behind). [`RecoveredState::torn_tail`] reports it, and
//!   [`StateStore::open`](super::StateStore::open) truncates it away
//!   before appending;
//! - anything a crash cannot explain — a CRC mismatch with complete
//!   records after it, an undecodable payload whose CRC passes, a
//!   non-monotonic sequence number, a bad header on a non-empty file —
//!   is a typed [`CorruptState`](super::CorruptState) error. Recovery
//!   never silently drops interior records.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::wal::{crc32_pair, decode_record, le_u32_at, HEADER_LEN,
                 MAX_RECORD_LEN, WAL_FILE, WAL_MAGIC};
use super::{snapshot, CorruptState, StateRecord, TenantState};

/// What [`recover`] reconstructed from a state directory.
#[derive(Clone, Debug, Default)]
pub struct RecoveredState {
    /// Live tenants after replay, sorted by tenant name.
    pub tenants: Vec<TenantState>,
    /// Highest sequence number covered (snapshot or WAL); appends
    /// continue at `last_seq + 1`.
    pub last_seq: u64,
    /// Entries loaded from the snapshot (0 if none existed).
    pub snapshot_entries: usize,
    /// Complete WAL records parsed (applied + skipped).
    pub wal_records: u64,
    /// WAL records skipped because the snapshot already covered them
    /// (the crash window between snapshot publish and WAL truncation).
    pub wal_skipped: u64,
    /// A torn trailing record was found (and will be truncated away on
    /// open).
    pub torn_tail: bool,
    /// Byte length of the valid WAL prefix (header + complete records).
    pub wal_valid_len: u64,
}

fn apply(state: &mut BTreeMap<String, TenantState>, rec: StateRecord) {
    match rec {
        StateRecord::Register(ts) | StateRecord::Swap(ts) => {
            state.insert(ts.tenant.clone(), ts);
        }
        StateRecord::Evict { tenant } => {
            state.remove(&tenant);
        }
    }
}

/// Replay `dir`'s snapshot + WAL into the state the registry should
/// restart with. Read-only: truncating the torn tail (if any) is the
/// opener's job, so `recover` can also be used for offline inspection
/// of a state directory that another process owns.
pub fn recover(dir: &Path) -> Result<RecoveredState> {
    let (snap_last_seq, mut state) = match snapshot::read(dir)
        .with_context(|| format!("recovering snapshot in {dir:?}"))?
    {
        Some((seq, entries)) => {
            let map: BTreeMap<String, TenantState> = entries
                .into_iter()
                .map(|ts| (ts.tenant.clone(), ts))
                .collect();
            (seq, map)
        }
        None => (0, BTreeMap::new()),
    };
    let snapshot_entries = state.len();

    let wal_path = dir.join(WAL_FILE);
    let bytes = match std::fs::read(&wal_path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
        Err(e) => {
            return Err(e).with_context(|| format!("read WAL {wal_path:?}"))
        }
    };
    let file = wal_path.display().to_string();
    let corrupt = |offset: u64, detail: String| -> anyhow::Error {
        CorruptState { file: file.clone(), offset, detail }.into()
    };

    let mut out = RecoveredState {
        last_seq: snap_last_seq,
        snapshot_entries,
        ..RecoveredState::default()
    };
    if bytes.is_empty() {
        // fresh directory (or a log that died before any byte hit disk)
        out.tenants = state.into_values().collect();
        return Ok(out);
    }
    if bytes.len() < HEADER_LEN {
        // the header itself was torn mid-write: nothing to replay
        out.torn_tail = true;
        out.tenants = state.into_values().collect();
        return Ok(out);
    }
    if &bytes[..4] != WAL_MAGIC {
        return Err(corrupt(0, "bad WAL magic".into()));
    }
    let version = le_u32_at(bytes, 4);
    if version != super::wal::FORMAT_VERSION {
        return Err(corrupt(4, format!("unsupported WAL format {version}")));
    }

    let mut off = HEADER_LEN;
    let mut prev_seq = 0u64;
    out.wal_valid_len = off as u64;
    while off < bytes.len() {
        if off + 8 > bytes.len() {
            out.torn_tail = true; // frame header cut mid-write
            break;
        }
        let len_bytes = &bytes[off..off + 4];
        let Ok(len) = usize::try_from(le_u32_at(bytes, off)) else {
            return Err(corrupt(
                off as u64,
                "frame length overflows usize".into(),
            ));
        };
        let crc = le_u32_at(bytes, off + 4);
        if off + 8 + len > bytes.len() {
            // a genuine torn append leaves strictly less than one frame
            // of trailing bytes; more than that can only mean a length
            // field corrupted to reach past EOF over complete records —
            // never silently discard those
            let tail = bytes.len() - off;
            if tail > MAX_RECORD_LEN + 8 {
                return Err(corrupt(
                    off as u64,
                    format!(
                        "frame claims {len} payload bytes past EOF but \
                         {tail} bytes follow — more than any single torn \
                         append could leave"
                    ),
                ));
            }
            out.torn_tail = true; // payload cut mid-write
            break;
        }
        if len > MAX_RECORD_LEN {
            // a full frame claiming an absurd length cannot come from a
            // truncated append — the length prefix is written before
            // any payload byte
            return Err(corrupt(
                off as u64,
                format!("record length {len} exceeds cap {MAX_RECORD_LEN}"),
            ));
        }
        let payload = &bytes[off + 8..off + 8 + len];
        if crc32_pair(len_bytes, payload) != crc {
            if off + 8 + len == bytes.len() {
                // garbled bytes with nothing after them: the trailing
                // append never completed
                out.torn_tail = true;
                break;
            }
            return Err(corrupt(
                off as u64,
                "record CRC mismatch with complete records after it".into(),
            ));
        }
        let (seq, rec) = decode_record(payload)
            .map_err(|detail| corrupt(off as u64, detail))?;
        if seq == 0 || seq <= prev_seq {
            return Err(corrupt(
                off as u64,
                format!(
                    "non-monotonic sequence {seq} after {prev_seq} \
                     (spliced or reordered log?)"
                ),
            ));
        }
        prev_seq = seq;
        out.wal_records += 1;
        if seq <= snap_last_seq {
            out.wal_skipped += 1; // the snapshot already includes it
        } else {
            apply(&mut state, rec);
            out.last_seq = seq;
        }
        off += 8 + len;
        out.wal_valid_len = off as u64;
    }
    out.last_seq = out.last_seq.max(prev_seq).max(snap_last_seq);
    out.tenants = state.into_values().collect();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::wal::encode_record;
    use crate::store::{Durability, StateStore};

    fn tdir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join("qp_recover_unit")
            .join(format!("{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn ts(tenant: &str, version: u64) -> TenantState {
        TenantState {
            tenant: tenant.to_string(),
            version,
            q: 3,
            n_layers: 1,
            checksum: 11,
            path: String::new(),
            thetas: vec![0.5; 9],
        }
    }

    #[test]
    fn empty_dir_and_empty_wal_recover_to_nothing() {
        let dir = tdir("empty");
        let r = recover(&dir).unwrap();
        assert!(r.tenants.is_empty());
        assert_eq!(r.last_seq, 0);
        assert!(!r.torn_tail);
        // a zero-byte WAL (crash before the header write) is fresh, a
        // half-header is a torn tail; neither is corruption
        std::fs::write(dir.join(WAL_FILE), b"").unwrap();
        assert!(!recover(&dir).unwrap().torn_tail);
        std::fs::write(dir.join(WAL_FILE), b"QPW").unwrap();
        let r = recover(&dir).unwrap();
        assert!(r.torn_tail);
        assert_eq!(r.wal_valid_len, 0);
    }

    #[test]
    fn bad_magic_is_corruption_not_torn() {
        let dir = tdir("magic");
        std::fs::write(dir.join(WAL_FILE), b"NOPE\x01\x00\x00\x00").unwrap();
        let e = recover(&dir).unwrap_err();
        assert!(e.downcast_ref::<CorruptState>().is_some(), "{e}");
    }

    #[test]
    fn non_monotonic_sequence_is_corruption() {
        let dir = tdir("seq");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"QPWL");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&encode_record(
            2,
            &StateRecord::Register(ts("a", 1)),
        ).unwrap());
        bytes.extend_from_slice(&encode_record(
            2,
            &StateRecord::Register(ts("b", 1)),
        ).unwrap());
        std::fs::write(dir.join(WAL_FILE), &bytes).unwrap();
        let e = recover(&dir).unwrap_err();
        let c = e.downcast_ref::<CorruptState>().expect("typed");
        assert!(c.detail.contains("non-monotonic"), "{c:?}");
    }

    #[test]
    fn snapshot_plus_stale_wal_skips_covered_records() {
        // simulate the crash window between snapshot publish and WAL
        // truncation: the WAL still holds records the snapshot covers
        let dir = tdir("skip");
        let store = StateStore::open(&dir, Durability::Buffered).unwrap().store;
        store.append(&StateRecord::Register(ts("a", 1))).unwrap();
        store.append(&StateRecord::Swap(ts("a", 2))).unwrap();
        drop(store);
        let wal_before = std::fs::read(dir.join(WAL_FILE)).unwrap();
        let store = StateStore::open(&dir, Durability::Buffered).unwrap().store;
        store.compact(&[ts("a", 2)]).unwrap();
        drop(store);
        // put the pre-compaction WAL back: both records now have
        // seq <= snapshot.last_seq and must be skipped, not re-applied
        std::fs::write(dir.join(WAL_FILE), &wal_before).unwrap();
        let r = recover(&dir).unwrap();
        assert_eq!(r.wal_records, 2);
        assert_eq!(r.wal_skipped, 2);
        assert_eq!(r.last_seq, 2);
        assert_eq!(r.tenants, vec![ts("a", 2)]);
    }
}
