//! Snapshot compaction: the live registry state as one checksummed
//! file, published by temp-file + atomic same-directory rename.
//!
//! ## On-disk layout
//!
//! ```text
//! magic "QPSS" | u32 format version (1)
//! body:  u64 last_seq | u32 count | count x tenant-state
//! u32 crc32(body)
//! ```
//!
//! `last_seq` pins the last WAL sequence number the snapshot includes:
//! recovery applies only WAL records *after* it, which is what makes
//! the crash window between "snapshot renamed" and "WAL truncated"
//! harmless — the still-present records replay as no-ops-by-skip.
//!
//! Atomicity: the file is fully written and fsynced under a hidden temp
//! name, then renamed over [`SNAPSHOT_FILE`] (same directory, so the
//! rename is atomic on POSIX). A reader therefore sees either the old
//! complete snapshot or the new complete snapshot, never a torn hybrid;
//! the whole-body CRC turns any other damage into a typed
//! [`CorruptState`](super::CorruptState) instead of a silent partial
//! load.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::wal::{crc32, decode_tenant_state, encode_tenant_state, le_u32_at,
                 put_u32, put_u64, validate_tenant_state, Reader};
use super::{CorruptState, TenantState};

/// Snapshot file name inside a state directory.
pub const SNAPSHOT_FILE: &str = "snapshot.qpst";

const SNAP_MAGIC: &[u8; 4] = b"QPSS";
const FORMAT_VERSION: u32 = 1;
/// Snapshot entry-count cap (far above any real registry, far below
/// anything that could size a hostile allocation).
const MAX_SNAPSHOT_ENTRIES: usize = 1 << 20;

/// Write `entries` as the snapshot for `dir`, covering WAL sequence
/// numbers up to and including `last_seq`. Fsynced before the rename
/// publishes it; the directory is fsynced (best effort) after, so the
/// rename itself survives a power cut.
pub(crate) fn write(dir: &Path, last_seq: u64, entries: &[TenantState])
                    -> Result<()> {
    // never publish what the reader would refuse (or mis-frame: the
    // u16 length prefixes would silently wrap past the caps) — a
    // CRC-valid-but-undecodable snapshot published over the good one
    // would brick the directory
    if entries.len() > MAX_SNAPSHOT_ENTRIES {
        bail!("refusing to snapshot {} entries (cap {MAX_SNAPSHOT_ENTRIES})",
              entries.len());
    }
    for ts in entries {
        validate_tenant_state(ts)
            .with_context(|| format!("snapshot entry {:?}", ts.tenant))?;
    }
    let mut body = Vec::with_capacity(64 * entries.len() + 16);
    put_u64(&mut body, last_seq);
    let count = u32::try_from(entries.len()).with_context(|| {
        format!("entry count {} overflows the u32 prefix", entries.len())
    })?;
    put_u32(&mut body, count);
    for ts in entries {
        encode_tenant_state(&mut body, ts)
            .with_context(|| format!("encode snapshot entry {:?}", ts.tenant))?;
    }
    let mut bytes = Vec::with_capacity(body.len() + 12);
    bytes.extend_from_slice(SNAP_MAGIC);
    bytes.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    bytes.extend_from_slice(&body);
    bytes.extend_from_slice(&crc32(&body).to_le_bytes());

    let tmp = dir.join(format!(".tmp.snapshot.{}", std::process::id()));
    std::fs::write(&tmp, &bytes)
        .with_context(|| format!("write snapshot temp {tmp:?}"))?;
    // fsync the temp before the rename: the rename must never publish a
    // name whose bytes are still only in the page cache
    std::fs::File::open(&tmp)
        .and_then(|f| f.sync_all())
        .with_context(|| format!("fsync snapshot temp {tmp:?}"))?;
    let dest = dir.join(SNAPSHOT_FILE);
    std::fs::rename(&tmp, &dest)
        .with_context(|| format!("publish snapshot {tmp:?} -> {dest:?}"))?;
    // persist the renamed directory entry: the caller truncates the WAL
    // right after this returns, so a rename that silently failed to
    // reach disk plus a power cut could otherwise recover a stale (or
    // empty) state from a clean-looking directory. A platform that
    // cannot open a directory handle at all has nothing to sync; one
    // that can open it but fails to sync it is a real error and must
    // block the WAL truncation.
    if let Ok(d) = std::fs::File::open(dir) {
        d.sync_all()
            .with_context(|| format!("fsync state dir {dir:?} after \
                                      snapshot publish"))?;
    }
    Ok(())
}

/// Read the snapshot for `dir`, if one exists: `(last_seq, entries)`.
/// A missing file is `Ok(None)` (first run / never compacted); any
/// damage is a typed [`CorruptState`](super::CorruptState) — the
/// atomic-rename protocol means a torn snapshot cannot happen through
/// crashes alone, so there is no tolerated-tail case here.
pub(crate) fn read(dir: &Path) -> Result<Option<(u64, Vec<TenantState>)>> {
    let path = dir.join(SNAPSHOT_FILE);
    let bytes = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => {
            return Err(e).with_context(|| format!("read snapshot {path:?}"))
        }
    };
    let file = path.display().to_string();
    let corrupt = |offset: u64, detail: String| CorruptState {
        file: file.clone(),
        offset,
        detail,
    };
    if bytes.len() < 8 + 12 {
        return Err(corrupt(
            0,
            format!("snapshot is only {} byte(s)", bytes.len()),
        )
        .into());
    }
    if &bytes[..4] != SNAP_MAGIC {
        return Err(corrupt(0, "bad snapshot magic".into()).into());
    }
    let version = le_u32_at(&bytes, 4);
    if version != FORMAT_VERSION {
        return Err(corrupt(
            4,
            format!("unsupported snapshot format version {version}"),
        )
        .into());
    }
    let body = &bytes[8..bytes.len() - 4];
    let stored = le_u32_at(&bytes, bytes.len() - 4);
    let computed = crc32(body);
    if stored != computed {
        return Err(corrupt(
            8,
            format!(
                "snapshot body CRC mismatch (stored {stored:08x}, \
                 computed {computed:08x})"
            ),
        )
        .into());
    }
    let mut r = Reader::new(body);
    let parse = |e: String| corrupt(8, e);
    let last_seq = r.u64("last_seq").map_err(parse)?;
    let count = usize::try_from(r.u32("entry count").map_err(parse)?)
        .map_err(|_| parse("entry count overflows usize".into()))?;
    if count > MAX_SNAPSHOT_ENTRIES {
        return Err(corrupt(
            8,
            format!("entry count {count} exceeds cap {MAX_SNAPSHOT_ENTRIES}"),
        )
        .into());
    }
    let mut entries = Vec::with_capacity(count);
    for _ in 0..count {
        entries.push(decode_tenant_state(&mut r).map_err(parse)?);
    }
    if r.remaining() != 0 {
        return Err(corrupt(
            8,
            format!("{} trailing byte(s) after the last entry", r.remaining()),
        )
        .into());
    }
    Ok(Some((last_seq, entries)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::CorruptState;

    fn tdir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join("qp_snapshot_unit")
            .join(format!("{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn ts(tenant: &str, version: u64) -> TenantState {
        TenantState {
            tenant: tenant.to_string(),
            version,
            q: 3,
            n_layers: 1,
            checksum: 7,
            path: String::new(),
            thetas: vec![0.25; 9],
        }
    }

    #[test]
    fn roundtrip_and_absent() {
        let dir = tdir("rt");
        assert!(read(&dir).unwrap().is_none());
        let entries = vec![ts("a", 2), ts("b", 1)];
        write(&dir, 17, &entries).unwrap();
        let (seq, back) = read(&dir).unwrap().unwrap();
        assert_eq!(seq, 17);
        assert_eq!(back, entries);
        // overwrite via rename: the new snapshot fully replaces the old
        write(&dir, 21, &entries[..1]).unwrap();
        let (seq, back) = read(&dir).unwrap().unwrap();
        assert_eq!(seq, 21);
        assert_eq!(back[..], entries[..1]);
        // no temp litter
        let stray: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with(".tmp."))
            .collect();
        assert!(stray.is_empty(), "{stray:?}");
    }

    #[test]
    fn undecodable_entries_are_refused_before_publishing() {
        let dir = tdir("caps");
        write(&dir, 1, &[ts("good", 1)]).unwrap();
        // an entry the reader would refuse must never replace the good
        // snapshot (put_str16's u16 prefix would wrap and the CRC would
        // happily cover the garbage)
        let mut bad = ts("x", 2);
        bad.tenant = "t".repeat(70_000);
        let e = write(&dir, 2, &[bad]).unwrap_err().to_string();
        assert!(e.contains("exceeds the WAL cap"), "{e}");
        // the previous snapshot is untouched and still reads back
        let (seq, back) = read(&dir).unwrap().unwrap();
        assert_eq!(seq, 1);
        assert_eq!(back, vec![ts("good", 1)]);
    }

    #[test]
    fn any_byte_flip_is_typed_corruption() {
        let dir = tdir("flip");
        write(&dir, 3, &[ts("t", 1)]).unwrap();
        let path = dir.join(SNAPSHOT_FILE);
        let clean = std::fs::read(&path).unwrap();
        for pos in [0usize, 5, 9, clean.len() / 2, clean.len() - 1] {
            let mut bad = clean.clone();
            bad[pos] ^= 0x40;
            std::fs::write(&path, &bad).unwrap();
            let e = read(&dir).unwrap_err();
            assert!(
                e.downcast_ref::<CorruptState>().is_some(),
                "pos={pos}: untyped error {e}"
            );
        }
        std::fs::write(&path, &clean).unwrap();
        assert!(read(&dir).unwrap().is_some());
    }
}
