//! The write-ahead log: an append-only file of length-prefixed,
//! CRC32-framed registry mutation records.
//!
//! ## On-disk layout
//!
//! ```text
//! header:  magic "QPWL" | u32 format version (1)
//! record:  u32 payload_len | u32 crc32(payload_len LE || payload)
//!          | payload
//! payload: u64 seq | u8 kind (1 register, 2 swap, 3 evict)
//!          register/swap: tenant-state (see below)
//!          evict:         u16 tenant_len | tenant utf8
//! tenant-state: u16 tenant_len | tenant utf8 | u64 version | u32 q
//!               | u32 n_layers | u64 theta checksum
//!               | u16 path_len | path utf8
//!               | u32 n_thetas | f32 LE thetas
//! ```
//!
//! Every record is written with a single `write_all`, so a crash leaves
//! at most a *prefix* of the last record on disk — which is exactly the
//! one torn trailing record [`mod@crate::store::recover`] tolerates. The
//! CRC covers the length prefix as well as the payload: the length is
//! what recovery uses to tell a torn tail from interior corruption, so
//! a bit-flipped length that stays in bounds is caught as corruption
//! rather than silently re-framing the log. (A length corrupted to
//! reach *past* EOF is indistinguishable from a genuine torn append by
//! construction; recovery bounds that ambiguity to less than one
//! frame's worth of trailing bytes.)
//!
//! Sequence numbers start at 1, increase by exactly 1 per append, and
//! survive compaction (the snapshot pins the last sequence it covers,
//! and the truncated WAL keeps counting from there) — recovery uses
//! them to skip records a snapshot already includes and to reject
//! spliced or reordered logs as [`CorruptState`](super::CorruptState).

use std::fs::{File, OpenOptions};
use std::io::{Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::{StateRecord, TenantState};
use crate::obs::hist::Hist;
use crate::obs::metrics::{detached_hist, Class, Counter, MetricsRegistry};
use crate::obs::span::SpanClock;

/// WAL file name inside a state directory.
pub const WAL_FILE: &str = "wal.log";

pub(crate) const WAL_MAGIC: &[u8; 4] = b"QPWL";
pub(crate) const FORMAT_VERSION: u32 = 1;
/// magic + format version.
pub(crate) const HEADER_LEN: usize = 8;

/// Far above any real record (a q = 12, many-layer adapter is ~KBs of
/// thetas), far below anything that could turn framing garbage into a
/// giant allocation.
pub(crate) const MAX_RECORD_LEN: usize = 1 << 24;
pub(crate) const MAX_WAL_TENANT_LEN: usize = 256;
pub(crate) const MAX_WAL_PATH_LEN: usize = 4096;
pub(crate) const MAX_WAL_THETAS: usize = 1 << 22;

/// How hard "appended" is. The knob trades append throughput against
/// the failure domain that can lose the WAL tail: `Buffered` survives
/// any *process* crash (the bytes are in the OS page cache) but a power
/// cut may drop the tail; `EveryN(n)` bounds that loss to n records;
/// `Always` fsyncs every append. Snapshots are always fsynced
/// regardless of this setting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Durability {
    /// No explicit fsync: OS-crash-safe tail only.
    #[default]
    Buffered,
    /// fsync after every n appends.
    EveryN(u64),
    /// fsync after every append.
    Always,
}

// ------------------------------------------------------------------ crc32 ---

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        // analyze: allow(framing-casts) const fn (no try_from); i < 256 so lossless
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = crc_table();
const CRC_INIT: u32 = 0xffff_ffff;

fn crc_feed(mut c: u32, data: &[u8]) -> u32 {
    for &b in data {
        c = CRC_TABLE[usize::from((c ^ u32::from(b)) as u8)] ^ (c >> 8);
    }
    c
}

/// CRC-32 (IEEE 802.3 polynomial), table-driven.
pub fn crc32(data: &[u8]) -> u32 {
    crc_feed(CRC_INIT, data) ^ 0xffff_ffff
}

/// CRC-32 of `a` followed by `b` without concatenating — the frame
/// checksum covers the length prefix *and* the payload (the length is
/// what decides torn-tail vs corruption at recovery, so it must not be
/// the one unprotected field).
pub(crate) fn crc32_pair(a: &[u8], b: &[u8]) -> u32 {
    crc_feed(crc_feed(CRC_INIT, a), b) ^ 0xffff_ffff
}

// --------------------------------------------------------- encode / decode ---

fn put_u16(buf: &mut Vec<u8>, x: u16) {
    buf.extend_from_slice(&x.to_le_bytes());
}

pub(crate) fn put_u32(buf: &mut Vec<u8>, x: u32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

pub(crate) fn put_u64(buf: &mut Vec<u8>, x: u64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_str16(buf: &mut Vec<u8>, s: &str) -> Result<()> {
    let len = u16::try_from(s.len()).with_context(|| {
        format!("string of {} bytes overflows the u16 length prefix", s.len())
    })?;
    put_u16(buf, len);
    buf.extend_from_slice(s.as_bytes());
    Ok(())
}

pub(crate) fn encode_tenant_state(buf: &mut Vec<u8>, ts: &TenantState)
                                  -> Result<()> {
    put_str16(buf, &ts.tenant)?;
    put_u64(buf, ts.version);
    put_u32(buf, ts.q);
    put_u32(buf, ts.n_layers);
    put_u64(buf, ts.checksum);
    put_str16(buf, &ts.path)?;
    let n_thetas = u32::try_from(ts.thetas.len()).with_context(|| {
        format!("theta count {} overflows the u32 prefix", ts.thetas.len())
    })?;
    put_u32(buf, n_thetas);
    for t in &ts.thetas {
        buf.extend_from_slice(&t.to_le_bytes());
    }
    Ok(())
}

/// Little-endian `u32` at `off`. The caller has already bounds-checked
/// `off + 4 <= bytes.len()` — the slice below is a range (never a bare
/// literal index) so a violation is a checked panic, not UB.
pub(crate) fn le_u32_at(bytes: &[u8], off: usize) -> u32 {
    let mut b = [0u8; 4];
    b.copy_from_slice(&bytes[off..off + 4]);
    u32::from_le_bytes(b)
}

/// Bounds-checked little-endian cursor over a CRC-verified payload.
/// Errors are plain detail strings; the recovery layer wraps them into
/// [`CorruptState`](super::CorruptState) with file and offset attached.
pub(crate) struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, pos: 0 }
    }

    pub(crate) fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "record ends short of {what} ({} byte(s) left, {n} needed)",
                self.remaining()
            ));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, String> {
        match self.take(1, what)? {
            [b] => Ok(*b),
            s => Err(format!("{what}: take(1) returned {} byte(s)", s.len())),
        }
    }

    fn u16(&mut self, what: &str) -> Result<u16, String> {
        let mut b = [0u8; 2];
        b.copy_from_slice(self.take(2, what)?);
        Ok(u16::from_le_bytes(b))
    }

    pub(crate) fn u32(&mut self, what: &str) -> Result<u32, String> {
        let mut b = [0u8; 4];
        b.copy_from_slice(self.take(4, what)?);
        Ok(u32::from_le_bytes(b))
    }

    pub(crate) fn u64(&mut self, what: &str) -> Result<u64, String> {
        let s = self.take(8, what)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(s);
        Ok(u64::from_le_bytes(b))
    }

    fn str16(&mut self, what: &str, cap: usize) -> Result<String, String> {
        let len = usize::from(self.u16(what)?);
        if len > cap {
            return Err(format!("{what} length {len} exceeds cap {cap}"));
        }
        let bytes = self.take(len, what)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| format!("{what} is not utf8"))
    }
}

pub(crate) fn decode_tenant_state(r: &mut Reader<'_>)
                                  -> Result<TenantState, String> {
    let tenant = r.str16("tenant", MAX_WAL_TENANT_LEN)?;
    let version = r.u64("version")?;
    let q = r.u32("q")?;
    let n_layers = r.u32("n_layers")?;
    let checksum = r.u64("checksum")?;
    let path = r.str16("path", MAX_WAL_PATH_LEN)?;
    let n_thetas = usize::try_from(r.u32("theta count")?)
        .map_err(|_| "theta count overflows usize".to_string())?;
    if n_thetas > MAX_WAL_THETAS {
        return Err(format!(
            "theta count {n_thetas} exceeds cap {MAX_WAL_THETAS}"
        ));
    }
    let bytes = r.take(n_thetas * 4, "theta payload")?;
    let thetas = bytes
        .chunks_exact(4)
        .map(|c| {
            let mut b = [0u8; 4];
            b.copy_from_slice(c);
            f32::from_le_bytes(b)
        })
        .collect();
    Ok(TenantState { tenant, version, q, n_layers, checksum, path, thetas })
}

const KIND_REGISTER: u8 = 1;
const KIND_SWAP: u8 = 2;
const KIND_EVICT: u8 = 3;

fn check_tenant(tenant: &str) -> Result<()> {
    if tenant.len() > MAX_WAL_TENANT_LEN {
        bail!("tenant id of {} bytes exceeds the WAL cap \
               {MAX_WAL_TENANT_LEN}", tenant.len());
    }
    Ok(())
}

/// Refuse to persist what the decoder would refuse to read — shared by
/// the WAL append and the snapshot writer, because both formats use
/// [`encode_tenant_state`] and `put_str16`'s `u16` length prefixes
/// would silently wrap past the caps. (The caps are all well under
/// `u16::MAX` / `u32::MAX`, so a validated value cannot wrap.)
pub(crate) fn validate_tenant_state(ts: &TenantState) -> Result<()> {
    check_tenant(&ts.tenant)?;
    if ts.path.len() > MAX_WAL_PATH_LEN {
        bail!("origin path of {} bytes exceeds the WAL cap \
               {MAX_WAL_PATH_LEN}", ts.path.len());
    }
    if ts.thetas.len() > MAX_WAL_THETAS {
        bail!("theta vector of {} entries exceeds the WAL cap \
               {MAX_WAL_THETAS}", ts.thetas.len());
    }
    Ok(())
}

/// A record must never be acknowledged as durable and then fail
/// recovery as an interior corruption.
fn validate_record(rec: &StateRecord) -> Result<()> {
    match rec {
        StateRecord::Register(ts) | StateRecord::Swap(ts) => {
            validate_tenant_state(ts)
        }
        StateRecord::Evict { tenant } => check_tenant(tenant),
    }
}

/// One framed record (length prefix + CRC + payload), ready for a
/// single `write_all`.
pub(crate) fn encode_record(seq: u64, rec: &StateRecord) -> Result<Vec<u8>> {
    let mut payload = Vec::with_capacity(64);
    put_u64(&mut payload, seq);
    match rec {
        StateRecord::Register(ts) => {
            payload.push(KIND_REGISTER);
            encode_tenant_state(&mut payload, ts)?;
        }
        StateRecord::Swap(ts) => {
            payload.push(KIND_SWAP);
            encode_tenant_state(&mut payload, ts)?;
        }
        StateRecord::Evict { tenant } => {
            payload.push(KIND_EVICT);
            put_str16(&mut payload, tenant)?;
        }
    }
    let payload_len = u32::try_from(payload.len()).with_context(|| {
        format!("payload of {} bytes overflows the u32 frame length",
                payload.len())
    })?;
    let len_bytes = payload_len.to_le_bytes();
    let mut frame = Vec::with_capacity(payload.len() + 8);
    frame.extend_from_slice(&len_bytes);
    put_u32(&mut frame, crc32_pair(&len_bytes, &payload));
    frame.extend_from_slice(&payload);
    Ok(frame)
}

/// Decode one CRC-verified payload back into (seq, record).
pub(crate) fn decode_record(payload: &[u8])
                            -> Result<(u64, StateRecord), String> {
    let mut r = Reader::new(payload);
    let seq = r.u64("seq")?;
    let kind = r.u8("kind")?;
    let rec = match kind {
        KIND_REGISTER => StateRecord::Register(decode_tenant_state(&mut r)?),
        KIND_SWAP => StateRecord::Swap(decode_tenant_state(&mut r)?),
        KIND_EVICT => StateRecord::Evict {
            tenant: r.str16("tenant", MAX_WAL_TENANT_LEN)?,
        },
        other => return Err(format!("unknown record kind {other}")),
    };
    if r.remaining() != 0 {
        return Err(format!(
            "{} trailing byte(s) after a complete record",
            r.remaining()
        ));
    }
    Ok((seq, rec))
}

// ----------------------------------------------------------------- writer ---

/// Writer-side metric handles: appended frame bytes, fsync count and
/// fsync latency. Byte and fsync *counts* are [`Class::Stable`] — they
/// are pure functions of the record stream and the [`Durability`]
/// cadence — while fsync *latency* is wall-clock territory and stays
/// [`Class::Volatile`]. Defaults to detached ([`WalObs::disabled`]);
/// [`StateStore::instrument`](super::StateStore::instrument) installs
/// live handles through [`WalWriter::set_obs`].
#[derive(Clone, Debug)]
pub struct WalObs {
    clock: Arc<SpanClock>,
    append_bytes: Arc<Counter>,
    fsyncs: Arc<Counter>,
    fsync_ns: Arc<Hist>,
}

impl WalObs {
    /// Register the writer metrics on `reg`. Re-registering returns
    /// handles onto the same metrics.
    pub fn register(reg: &MetricsRegistry) -> WalObs {
        WalObs {
            clock: reg.clock(),
            append_bytes: reg.counter("wal_append_bytes_total", &[], Class::Stable),
            fsyncs: reg.counter("wal_fsyncs_total", &[], Class::Stable),
            fsync_ns: reg.hist("wal_fsync_ns", &[], Class::Volatile),
        }
    }

    /// Detached handles: the writer runs identically, nothing exports.
    pub fn disabled() -> WalObs {
        WalObs {
            clock: Arc::new(SpanClock::new(true)),
            append_bytes: Counter::detached(),
            fsyncs: Counter::detached(),
            fsync_ns: detached_hist(),
        }
    }

    pub fn append_bytes(&self) -> u64 {
        self.append_bytes.get()
    }

    pub fn fsyncs(&self) -> u64 {
        self.fsyncs.get()
    }
}

/// The append half of the WAL. Opened by
/// [`StateStore::open`](super::StateStore::open) after recovery has
/// established how much of an existing log is valid; a torn trailing
/// record is truncated away here, so appends always start at a clean
/// record boundary.
pub struct WalWriter {
    file: File,
    durability: Durability,
    next_seq: u64,
    appended_since_sync: u64,
    records_since_truncate: u64,
    obs: WalObs,
}

impl WalWriter {
    /// Open for appending. `valid_len` is the byte length of the valid
    /// record prefix ([`recover`](super::recover::recover) computed it);
    /// anything beyond is a torn tail and is cut. `next_seq` is the
    /// sequence number the next append will use.
    pub(crate) fn open(path: &Path, valid_len: u64, next_seq: u64,
                       durability: Durability) -> Result<WalWriter> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)
            .with_context(|| format!("open WAL {path:?}"))?;
        if valid_len < HEADER_LEN as u64 {
            // fresh log (or one that died before its header hit disk)
            file.set_len(0)
                .with_context(|| format!("reset WAL {path:?}"))?;
            file.seek(SeekFrom::Start(0))?;
            file.write_all(WAL_MAGIC)?;
            file.write_all(&FORMAT_VERSION.to_le_bytes())?;
            // a brand-new log's *directory entry* must survive a power
            // cut too: per-append fdatasync covers file contents, never
            // the entry — without this, Always/EveryN could lose the
            // whole file at once instead of the documented bounded
            // tail. One-time cost; the directory handle sync is best
            // effort (not every platform supports it).
            file.sync_all()
                .with_context(|| format!("fsync new WAL {path:?}"))?;
            if let Some(parent) = path.parent() {
                // a platform that cannot open a directory handle has
                // nothing to sync; one that can but fails to sync it is
                // a real durability error
                if let Ok(d) = File::open(parent) {
                    d.sync_all().with_context(|| format!(
                        "fsync WAL directory {parent:?}"))?;
                }
            }
        } else {
            file.set_len(valid_len)
                .with_context(|| format!("truncate torn WAL tail {path:?}"))?;
            file.seek(SeekFrom::End(0))?;
        }
        Ok(WalWriter {
            file,
            durability,
            next_seq: next_seq.max(1),
            appended_since_sync: 0,
            records_since_truncate: 0,
            obs: WalObs::disabled(),
        })
    }

    /// Install live metric handles (the writer opens detached).
    pub fn set_obs(&mut self, obs: WalObs) {
        self.obs = obs;
    }

    /// `sync_data` with fsync accounting: every explicit data sync in
    /// the writer funnels through here so the count matches the
    /// [`Durability`] contract exactly (the one-time `sync_all` that
    /// seats a brand-new header is setup, not cadence, and is excluded).
    fn fsync_data(&mut self, what: &'static str) -> Result<()> {
        let start = self.obs.clock.now_ns();
        self.file.sync_data().context(what)?;
        self.obs.fsync_ns.record(self.obs.clock.now_ns().saturating_sub(start));
        self.obs.fsyncs.inc();
        Ok(())
    }

    /// Append one record in a single write, then apply the fsync
    /// discipline. Returns the record's sequence number.
    ///
    /// A failed append rolls the file back to the pre-append length: a
    /// partial frame left *mid-log* would make every later append
    /// unrecoverable (recovery only tolerates a torn record at the
    /// tail), and callers like the spool's deferred-eviction path are
    /// expected to retry after an error.
    pub fn append(&mut self, rec: &StateRecord) -> Result<u64> {
        validate_record(rec)?;
        let seq = self.next_seq;
        let frame = encode_record(seq, rec)
            .with_context(|| format!("encode WAL record seq {seq}"))?;
        // belt to validate_record's braces: the *encoded* payload must
        // also clear the decoder's frame-length cap (a theta vector at
        // its own cap plus framing overhead could otherwise slip past
        // the per-field checks and brick recovery)
        if frame.len() - 8 > MAX_RECORD_LEN {
            bail!("encoded record of {} bytes exceeds the WAL frame cap \
                   {MAX_RECORD_LEN}", frame.len() - 8);
        }
        let clean_len = self.file.stream_position()
            .context("read WAL position")?;
        if let Err(e) = self.write_frame(&frame) {
            // best effort: truncate the partial frame (or the record
            // whose fsync failed — the caller will treat the mutation
            // as not-applied, so the log must agree) and re-seat the
            // cursor on the clean boundary
            let _ = self.file.set_len(clean_len);
            let _ = self.file.seek(SeekFrom::Start(clean_len));
            return Err(e)
                .with_context(|| format!("append WAL record seq {seq}"));
        }
        self.next_seq += 1;
        self.records_since_truncate += 1;
        self.obs
            .append_bytes
            .add(u64::try_from(frame.len()).unwrap_or(u64::MAX));
        Ok(seq)
    }

    fn write_frame(&mut self, frame: &[u8]) -> Result<()> {
        self.file.write_all(frame)?;
        match self.durability {
            Durability::Buffered => {}
            Durability::Always => {
                self.fsync_data("fsync WAL append")?;
            }
            Durability::EveryN(n) => {
                self.appended_since_sync += 1;
                if self.appended_since_sync >= n.max(1) {
                    self.fsync_data("fsync WAL batch")?;
                    self.appended_since_sync = 0;
                }
            }
        }
        Ok(())
    }

    /// Drop every record (the snapshot now covers them) but keep the
    /// sequence counter running. Always fsynced: a compaction boundary
    /// must never be weaker than the log it replaced.
    pub fn truncate_to_header(&mut self) -> Result<()> {
        self.file.set_len(HEADER_LEN as u64)?;
        self.file.seek(SeekFrom::End(0))?;
        self.fsync_data("fsync WAL truncation")?;
        self.appended_since_sync = 0;
        self.records_since_truncate = 0;
        Ok(())
    }

    /// Force everything appended so far to disk.
    pub fn sync(&mut self) -> Result<()> {
        self.fsync_data("fsync WAL")?;
        self.appended_since_sync = 0;
        Ok(())
    }

    pub fn last_seq(&self) -> u64 {
        self.next_seq - 1
    }

    pub fn records_since_truncate(&self) -> u64 {
        self.records_since_truncate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ts(tenant: &str) -> TenantState {
        TenantState {
            tenant: tenant.to_string(),
            version: 3,
            q: 4,
            n_layers: 2,
            checksum: 0xdead_beef_cafe_f00d,
            path: "/spool/x.qpck".into(),
            thetas: vec![0.5, -0.25, 1.5],
        }
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // IEEE CRC-32 of "123456789" is the classic check value
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn record_roundtrip_all_kinds() {
        for rec in [
            StateRecord::Register(ts("a")),
            StateRecord::Swap(ts("b")),
            StateRecord::Evict { tenant: "c".into() },
        ] {
            let frame = encode_record(7, &rec).unwrap();
            let len = u32::from_le_bytes(frame[0..4].try_into().unwrap()) as usize;
            let crc = u32::from_le_bytes(frame[4..8].try_into().unwrap());
            let payload = &frame[8..];
            assert_eq!(payload.len(), len);
            assert_eq!(crc32_pair(&frame[0..4], payload), crc);
            let (seq, back) = decode_record(payload).unwrap();
            assert_eq!(seq, 7);
            assert_eq!(back, rec);
        }
    }

    #[test]
    fn decode_rejects_truncation_trailing_bytes_and_bad_kind() {
        let frame = encode_record(1, &StateRecord::Register(ts("t")));
        let payload = &frame[8..];
        // every strict prefix of the payload must fail to decode
        for cut in 0..payload.len() {
            assert!(decode_record(&payload[..cut]).is_err(), "cut={cut}");
        }
        // trailing garbage after a complete record is corruption
        let mut padded = payload.to_vec();
        padded.push(0);
        let e = decode_record(&padded).unwrap_err();
        assert!(e.contains("trailing"), "{e}");
        // unknown kind byte
        let mut bad = payload.to_vec();
        bad[8] = 99;
        let e = decode_record(&bad).unwrap_err();
        assert!(e.contains("unknown record kind"), "{e}");
    }

    #[test]
    fn undecodable_records_are_refused_at_append() {
        let dir = std::env::temp_dir()
            .join("qp_wal_unit")
            .join(format!("caps_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(WAL_FILE);
        let mut w =
            WalWriter::open(&path, 0, 1, Durability::Buffered).unwrap();
        // every field the decoder caps is refused before any byte is
        // written — an acknowledged append must never fail recovery
        let mut bad = ts("t");
        bad.tenant = "x".repeat(MAX_WAL_TENANT_LEN + 1);
        assert!(w.append(&StateRecord::Register(bad)).is_err());
        let mut bad = ts("t");
        bad.path = "p".repeat(MAX_WAL_PATH_LEN + 1);
        assert!(w.append(&StateRecord::Swap(bad)).is_err());
        assert!(w
            .append(&StateRecord::Evict {
                tenant: "e".repeat(MAX_WAL_TENANT_LEN + 1),
            })
            .is_err());
        // the log is untouched (header only) and still appends cleanly
        assert_eq!(w.last_seq(), 0);
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            HEADER_LEN as u64
        );
        assert_eq!(w.append(&StateRecord::Register(ts("ok"))).unwrap(), 1);
    }

    #[test]
    fn decode_caps_hostile_lengths() {
        // a payload claiming a huge theta count must fail on the cap,
        // not attempt the allocation
        let mut payload = Vec::new();
        payload.extend_from_slice(&1u64.to_le_bytes());
        payload.push(1); // register
        payload.extend_from_slice(&1u16.to_le_bytes());
        payload.push(b't');
        payload.extend_from_slice(&1u64.to_le_bytes()); // version
        payload.extend_from_slice(&3u32.to_le_bytes()); // q
        payload.extend_from_slice(&1u32.to_le_bytes()); // n_layers
        payload.extend_from_slice(&0u64.to_le_bytes()); // checksum
        payload.extend_from_slice(&0u16.to_le_bytes()); // path ""
        payload.extend_from_slice(&u32::MAX.to_le_bytes()); // theta count
        let e = decode_record(&payload).unwrap_err();
        assert!(e.contains("exceeds cap"), "{e}");
    }
}
