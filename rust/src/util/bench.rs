//! Tiny benchmarking harness (criterion is unavailable offline): warmup,
//! repeated timed runs, median + MAD, and a stable one-line report format
//! every `cargo bench` target uses.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "bench {:<44} {:>12} /iter (±{:>10}, n={})",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mad_ns),
            self.iters
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` repeatedly: a warmup pass, then up to `max_runs` timed runs or
/// `budget_ms` of wall clock, whichever first. Prints and returns stats.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    f(); // warmup
    let budget = std::time::Duration::from_millis(budget_ms);
    let start = Instant::now();
    let mut samples: Vec<f64> = Vec::new();
    let max_runs = 1000;
    while samples.len() < 3 || (start.elapsed() < budget && samples.len() < max_runs) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    let r = BenchResult {
        name: name.to_string(),
        median_ns: median,
        mad_ns: mad,
        iters: samples.len(),
    };
    println!("{}", r.report());
    r
}

/// Prevent the optimizer from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 20, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.median_ns > 0.0);
        assert!(r.iters >= 3);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
