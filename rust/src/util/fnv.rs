//! FNV-1a (64-bit) — the repository's one integrity/identity digest,
//! shared by the adapter theta checksum
//! ([`crate::serve::registry::theta_checksum`]), the `QPCK` v3
//! checkpoint payload trailer, and the durable state records that carry
//! both. One definition, so the constants can never drift between the
//! writers and the verifiers.
//!
//! Why FNV-1a here: the per-byte step `h = (h ^ b) * PRIME` is a
//! bijection on `h` for a fixed byte and injective in the byte for a
//! fixed `h`, so any *same-length single-byte substitution* provably
//! changes the digest — the exact guarantee the corruption-detection
//! tests pin. (It is not cryptographic; authenticity is future work.)

/// FNV-1a 64-bit offset basis.
pub const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold `bytes` into a running digest (seed with [`OFFSET`]).
pub fn update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// One-shot digest of a byte slice.
pub fn hash(bytes: &[u8]) -> u64 {
    update(OFFSET, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_known_vectors() {
        // standard FNV-1a 64 test vectors
        assert_eq!(hash(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(hash(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(hash(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn incremental_equals_one_shot() {
        let h = update(update(OFFSET, b"foo"), b"bar");
        assert_eq!(h, hash(b"foobar"));
    }
}
