//! Minimal JSON parser/serializer (manifest.json, event logs, results).
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! f64 (manifest shapes are small integers, loss values are floats).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json { Json::Num(n) }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json { Json::Num(n as f64) }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json { Json::Str(s.to_string()) }
}
impl From<String> for Json {
    fn from(s: String) -> Json { Json::Str(s) }
}

/// Convenience constructor for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected EOF"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}",
                  c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()
            .map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut bytes: Vec<u8> = Vec::new();
        let out = loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => break String::from_utf8(bytes)?,
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    let mut push_char = |c: char, bytes: &mut Vec<u8>| {
                        let mut buf = [0u8; 4];
                        bytes.extend_from_slice(c.encode_utf8(&mut buf).as_bytes());
                    };
                    match e {
                        b'"' => bytes.push(b'"'),
                        b'\\' => bytes.push(b'\\'),
                        b'/' => bytes.push(b'/'),
                        b'n' => bytes.push(b'\n'),
                        b't' => bytes.push(b'\t'),
                        b'r' => bytes.push(b'\r'),
                        b'b' => bytes.push(8),
                        b'f' => bytes.push(12),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            push_char(char::from_u32(cp)
                                .ok_or_else(|| anyhow!("bad \\u{hex}"))?, &mut bytes);
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => bytes.push(c),
            }
        };
        Ok(out)
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} at byte {}, got {:?}", self.i, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true}, "e": null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"shape": [16, 24], "dtype": "int32"}"#).unwrap();
        assert_eq!(v.get("dtype").unwrap().as_str().unwrap(), "int32");
        let shape: Vec<usize> = v.get("shape").unwrap().as_arr().unwrap()
            .iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![16, 24]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é");
    }
}
