//! Self-contained infrastructure (the image has no registry access beyond
//! the `xla` closure): JSON, a seeded RNG, a tiny bench timer, a
//! work-stealing thread pool, and a property-testing helper used across
//! the test suite.

pub mod bench;
pub mod fnv;
pub mod json;
pub mod pool;
pub mod rng;
pub mod sync;

/// Best-effort text of a caught panic payload (shared by the pool's task
/// containment and the compile cache's init containment).
pub(crate) fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// proptest-lite: run `f` over `n` seeded random cases; panics with the
/// failing seed for reproduction. Used where the real proptest crate
/// would be (coordinator/quantum invariants).
pub fn check_property<F: Fn(&mut rng::Rng)>(name: &str, n: usize, f: F) {
    for case in 0..n {
        let seed = 0x9e3779b9_u64.wrapping_mul(case as u64 + 1) ^ 0xdead_beef;
        let mut rng = rng::Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng)
        }));
        if let Err(e) = result {
            panic!("property {name} failed at case {case} (seed {seed:#x}): {e:?}");
        }
    }
}
