//! Work-stealing thread pool for embarrassingly parallel experiment grids
//! (std threads + mutex deques — no external deps, per the offline image).
//!
//! Design, in service of *deterministic* sweeps:
//! - every task carries its input index; results are returned **in input
//!   order** regardless of which worker ran what or when it finished, so
//!   downstream aggregation is byte-identical to sequential execution;
//! - tasks are dealt round-robin into per-worker deques; a worker pops
//!   from the back of its own deque (LIFO, cache-friendly) and, when
//!   empty, steals from the front of a victim's deque (FIFO — steals the
//!   oldest, largest-remaining work first);
//! - each worker owns private state `S` built by `init(worker_id)` (for
//!   sweeps: its own PJRT runtime + compile cache), so no shared mutable
//!   state crosses threads besides the queues and result slots;
//! - a panicking task is caught and surfaced as an `Err` for that item —
//!   the pool never hangs or aborts the process;
//! - the first failing task aborts the pool (fail-fast, matching the
//!   sequential sweep's early return): finished tasks keep their
//!   results, still-queued tasks report a skip error that embeds the
//!   root cause, and no further compute is wasted on a doomed grid;
//! - a worker whose `init` fails simply exits; its dealt items are stolen
//!   by surviving workers. Only if *every* worker fails do items report
//!   an init error.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, Result};

use super::panic_msg;
use crate::obs::metrics::{Class, Counter, Gauge, MetricsRegistry};
use crate::obs::span::SpanClock;

/// Identity of one task execution: which worker ran it, which input slot.
#[derive(Clone, Copy, Debug)]
pub struct TaskCtx {
    pub worker: usize,
    pub index: usize,
}

/// Number of workers to use when the caller asks for "auto".
pub fn default_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Parse a user-facing worker-count value (`--jobs`, `$REPRO_JOBS`):
/// "auto" or "0" means one worker per core, otherwise a count. One
/// shared definition so the CLI flag and the env var can't drift.
pub fn parse_jobs_value(s: &str) -> Result<usize> {
    let t = s.trim();
    if t == "auto" || t == "0" {
        return Ok(default_jobs());
    }
    t.parse::<usize>()
        .map_err(|_| anyhow!("expected a worker count or 'auto', got {s:?}"))
}

/// Per-pool observability handles: steal/park/panic counters, a queue
/// depth gauge, and one busy-nanoseconds counter per worker (the
/// utilization numerator; the denominator is the session wall time).
/// All `pool_*` metrics are scheduling-dependent and therefore
/// `Volatile` — present in timed-mode exports, excluded from
/// deterministic ones.
#[derive(Clone, Debug)]
pub struct PoolObs {
    clock: Arc<SpanClock>,
    steals: Arc<Counter>,
    parks: Arc<Counter>,
    panics: Arc<Counter>,
    queue_depth: Arc<Gauge>,
    worker_busy_ns: Vec<Arc<Counter>>,
}

impl PoolObs {
    /// Register `pool_*` metrics for a pool named `pool` with up to
    /// `workers` workers (per-worker busy counters are labeled
    /// `worker=<id>`).
    pub fn register(reg: &MetricsRegistry, pool: &str, workers: usize) -> PoolObs {
        let mut worker_busy_ns = Vec::with_capacity(workers.max(1));
        for w in 0..workers.max(1) {
            let id = w.to_string();
            worker_busy_ns.push(reg.counter(
                "pool_worker_busy_ns",
                &[("pool", pool), ("worker", &id)],
                Class::Volatile,
            ));
        }
        PoolObs {
            clock: reg.clock(),
            steals: reg.counter("pool_steals_total", &[("pool", pool)], Class::Volatile),
            parks: reg.counter("pool_parks_total", &[("pool", pool)], Class::Volatile),
            panics: reg
                .counter("pool_task_panics_total", &[("pool", pool)], Class::Volatile),
            queue_depth: reg.gauge("pool_queue_depth", &[("pool", pool)], Class::Volatile),
        }
    }

    /// Detached handles: the instrumented paths run identically with
    /// nothing exported (the default for every `run_*` wrapper).
    pub fn disabled() -> PoolObs {
        PoolObs {
            clock: Arc::new(SpanClock::new(true)),
            steals: Counter::detached(),
            parks: Counter::detached(),
            panics: Counter::detached(),
            queue_depth: Gauge::detached(),
            worker_busy_ns: vec![Counter::detached()],
        }
    }

    fn busy(&self, w: usize) -> &Counter {
        // a disabled handle holds one shared slot for every worker
        &self.worker_busy_ns[w.min(self.worker_busy_ns.len() - 1)]
    }

    pub fn steals(&self) -> u64 {
        self.steals.get()
    }

    pub fn parks(&self) -> u64 {
        self.parks.get()
    }

    pub fn panics(&self) -> u64 {
        self.panics.get()
    }

    pub fn busy_ns(&self, w: usize) -> u64 {
        self.busy(w).get()
    }
}

fn run_one<T, R, S, W>(
    work: &W,
    state: &mut S,
    ctx: TaskCtx,
    item: T,
    obs: &PoolObs,
) -> Result<R>
where
    W: Fn(&mut S, TaskCtx, T) -> Result<R>,
{
    let t0 = obs.clock.now_ns();
    let r = match catch_unwind(AssertUnwindSafe(|| work(state, ctx, item))) {
        Ok(r) => r,
        Err(p) => {
            obs.panics.inc();
            Err(anyhow!(
                "task {} panicked in worker {}: {}",
                ctx.index,
                ctx.worker,
                panic_msg(p.as_ref())
            ))
        }
    };
    obs.busy(ctx.worker).add(obs.clock.now_ns().saturating_sub(t0));
    r
}

type Queue<T> = Mutex<VecDeque<(usize, T)>>;

fn pop_own<T>(queues: &[Queue<T>], w: usize) -> Option<(usize, T)> {
    queues[w].lock().unwrap().pop_back()
}

fn steal<T>(queues: &[Queue<T>], w: usize) -> Option<(usize, T)> {
    let jobs = queues.len();
    for d in 1..jobs {
        let victim = (w + d) % jobs;
        if let Some(t) = queues[victim].lock().unwrap().pop_front() {
            return Some(t);
        }
    }
    None
}

/// Execute `items` on up to `jobs` workers, each with private state from
/// `init(worker_id)`. Returns one `Result` per item, **in input order**.
///
/// `jobs <= 1` (or a single item) runs inline on the caller's thread with
/// zero pool overhead — the two paths produce identical outputs for pure
/// `work` functions, which is the sweep determinism guarantee.
pub fn run_stateful<T, R, S, I, W>(
    jobs: usize,
    items: Vec<T>,
    init: I,
    work: W,
) -> Vec<Result<R>>
where
    T: Send,
    R: Send,
    I: Fn(usize) -> Result<S> + Sync,
    W: Fn(&mut S, TaskCtx, T) -> Result<R> + Sync,
{
    run_stateful_obs(jobs, items, init, work, &PoolObs::disabled())
}

/// [`run_stateful`] with pool observability: steals, panics, queue
/// depth and per-worker busy time land on `obs`.
pub fn run_stateful_obs<T, R, S, I, W>(
    jobs: usize,
    items: Vec<T>,
    init: I,
    work: W,
    obs: &PoolObs,
) -> Vec<Result<R>>
where
    T: Send,
    R: Send,
    I: Fn(usize) -> Result<S> + Sync,
    W: Fn(&mut S, TaskCtx, T) -> Result<R> + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let jobs = jobs.max(1).min(n);
    if jobs == 1 {
        let mut out = Vec::with_capacity(n);
        let state0 = catch_unwind(AssertUnwindSafe(|| init(0))).unwrap_or_else(|p| {
            Err(anyhow!("init panicked: {}", panic_msg(p.as_ref())))
        });
        match state0 {
            Ok(mut state) => {
                let mut failed: Option<(usize, String)> = None;
                for (i, item) in items.into_iter().enumerate() {
                    if let Some((j, msg)) = &failed {
                        out.push(Err(skip_error(i, *j, msg)));
                        continue;
                    }
                    let ctx = TaskCtx { worker: 0, index: i };
                    let r = run_one(&work, &mut state, ctx, item, obs);
                    if let Err(e) = &r {
                        failed = Some((i, e.to_string()));
                    }
                    out.push(r);
                }
            }
            Err(e) => {
                let msg = format!("worker 0 init failed: {e}");
                for _ in 0..n {
                    out.push(Err(anyhow!("{msg}")));
                }
            }
        }
        return out;
    }

    let queues: Vec<Queue<T>> = (0..jobs).map(|_| Mutex::new(VecDeque::new())).collect();
    for (i, item) in items.into_iter().enumerate() {
        queues[i % jobs].lock().unwrap().push_back((i, item));
    }
    obs.queue_depth.set(n as i64);
    let slots: Vec<Mutex<Option<Result<R>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let init_errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let abort = AtomicBool::new(false);
    // lowest-index failure seen so far; skip errors embed its message so
    // whichever error surfaces first carries the root cause
    let first_error: Mutex<Option<(usize, String)>> = Mutex::new(None);

    std::thread::scope(|scope| {
        for w in 0..jobs {
            let queues = &queues;
            let slots = &slots;
            let init = &init;
            let work = &work;
            let init_errors = &init_errors;
            let abort = &abort;
            let first_error = &first_error;
            scope.spawn(move || {
                // contain init panics too — a worker that cannot start
                // must exit quietly (its deque gets stolen), not take the
                // process down when the scope re-raises
                let mut state = match catch_unwind(AssertUnwindSafe(|| init(w))) {
                    Ok(Ok(s)) => s,
                    Ok(Err(e)) => {
                        init_errors.lock().unwrap().push(format!("worker {w}: {e}"));
                        return;
                    }
                    Err(p) => {
                        init_errors.lock().unwrap().push(format!(
                            "worker {w}: init panicked: {}", panic_msg(p.as_ref())));
                        return;
                    }
                };
                while !abort.load(Ordering::Relaxed) {
                    let Some((i, item)) = pop_own(queues, w).or_else(|| {
                        let stolen = steal(queues, w);
                        if stolen.is_some() {
                            obs.steals.inc();
                        }
                        stolen
                    }) else {
                        break;
                    };
                    obs.queue_depth.add(-1);
                    let ctx = TaskCtx { worker: w, index: i };
                    let r = run_one(work, &mut state, ctx, item, obs);
                    if let Err(e) = &r {
                        let mut fe = first_error.lock().unwrap();
                        let lowest_so_far = match fe.as_ref() {
                            Some((j, _)) => i < *j,
                            None => true,
                        };
                        if lowest_so_far {
                            *fe = Some((i, e.to_string()));
                        }
                        abort.store(true, Ordering::Relaxed);
                    }
                    *slots[i].lock().unwrap() = Some(r);
                }
            });
        }
    });

    obs.queue_depth.set(0);
    let init_errors = init_errors.into_inner().unwrap();
    let first_error = first_error.into_inner().unwrap();
    slots
        .into_iter()
        .enumerate()
        .map(|(i, slot)| {
            slot.into_inner().unwrap().unwrap_or_else(|| match &first_error {
                Some((j, msg)) => Err(skip_error(i, *j, msg)),
                None => Err(anyhow!(
                    "task {i} was never executed (worker init failures: [{}])",
                    init_errors.join("; ")
                )),
            })
        })
        .collect()
}

fn skip_error(i: usize, failed: usize, msg: &str) -> anyhow::Error {
    anyhow!("task {i} skipped: pool aborted after task {failed} failed: {msg}")
}

// ---------------------------------------------------------------- service ---

struct ServiceState<T> {
    queue: VecDeque<(usize, T)>,
    seq: usize,
    closed: bool,
    live_workers: usize,
}

/// A long-running work queue for service-style pools (the adapter-serving
/// scheduler), complementing the batch-oriented [`run_stateful`]: items
/// arrive over time via [`push`](Service::push) and workers loop popping
/// until the queue is closed and drained.
///
/// Liveness contract — a `Service` never strands an item silently:
/// - `push` after `close`, or after every worker has exited, *drops* the
///   item immediately (items are expected to carry their own completion
///   channel whose `Drop` reports the failure, as the serve scheduler's
///   pending requests do);
/// - when the last worker exits while items are still queued, the queue
///   is drained and those items are dropped the same way, so a caller
///   blocked on an item's completion channel always wakes.
pub struct Service<T> {
    state: Mutex<ServiceState<T>>,
    cv: Condvar,
    init_errors: Mutex<Vec<String>>,
    obs: PoolObs,
}

impl<T> Service<T> {
    fn new(workers: usize, obs: PoolObs) -> Service<T> {
        Service {
            state: Mutex::new(ServiceState {
                queue: VecDeque::new(),
                seq: 0,
                closed: false,
                live_workers: workers,
            }),
            cv: Condvar::new(),
            init_errors: Mutex::new(Vec::new()),
            obs,
        }
    }

    /// Enqueue one item; returns its submission sequence number. If the
    /// queue is closed or every worker has exited, the item is dropped
    /// (see the liveness contract above) but a sequence number is still
    /// consumed so numbering stays gap-free from the caller's view.
    pub fn push(&self, item: T) -> usize {
        let dropped;
        let seq;
        {
            let mut st = self.state.lock().unwrap();
            seq = st.seq;
            st.seq += 1;
            if st.closed || st.live_workers == 0 {
                dropped = Some(item);
            } else {
                st.queue.push_back((seq, item));
                self.obs.queue_depth.add(1);
                dropped = None;
            }
        }
        if dropped.is_none() {
            self.cv.notify_one();
        }
        drop(dropped); // outside the lock: item Drop may take other locks
        seq
    }

    /// Pending (not yet popped) item count — the scheduler's queue-depth
    /// gauge reads this.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close the queue: workers drain what is already queued, then exit.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Worker init failures, for diagnostics after the pool winds down.
    pub fn init_errors(&self) -> Vec<String> {
        self.init_errors.lock().unwrap().clone()
    }

    /// Blocking worker-side pop: an item, or `None` once the queue is
    /// closed and empty.
    fn pop(&self) -> Option<(usize, T)> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(it) = st.queue.pop_front() {
                self.obs.queue_depth.add(-1);
                return Some(it);
            }
            if st.closed {
                return None;
            }
            self.obs.parks.inc();
            st = self.cv.wait(st).unwrap();
        }
    }

    /// One worker is gone. When the last one goes, strand-drain the queue
    /// (dropped items report through their own completion channels).
    fn worker_exit(&self) {
        let drained: Vec<(usize, T)>;
        {
            let mut st = self.state.lock().unwrap();
            st.live_workers = st.live_workers.saturating_sub(1);
            if st.live_workers > 0 {
                return;
            }
            drained = st.queue.drain(..).collect();
        }
        drop(drained); // outside the lock, as in push
        self.obs.queue_depth.set(0);
        self.cv.notify_all();
    }
}

/// Run a service pool: `jobs` workers (each with private state from
/// `init(worker_id)`, as in [`run_stateful`]) loop over a shared
/// [`Service`] queue while `body` runs on the caller's thread, submitting
/// items through the `&Service` it receives. When `body` returns the
/// queue closes, workers drain it, and `body`'s value is returned along
/// with every worker-init failure (collected after all workers have
/// exited, so the list is complete — callers should surface it when the
/// session failed, since dropped items only report a generic error).
///
/// `work` is infallible by signature: service items own their error
/// reporting (a completion channel filled on drop), so a failed or
/// panicking item never wedges the pool — the panic is contained and the
/// item's drop runs during unwind.
pub fn run_service<T, S, R, I, W, B>(jobs: usize, init: I, work: W, body: B)
                                     -> (R, Vec<String>)
where
    T: Send,
    I: Fn(usize) -> Result<S> + Sync,
    W: Fn(&mut S, TaskCtx, T) + Sync,
    B: FnOnce(&Service<T>) -> R,
{
    run_service_obs(jobs, init, work, body, PoolObs::disabled())
}

/// [`run_service`] with pool observability: parks, panics, queue depth
/// and per-worker busy time land on `obs`.
pub fn run_service_obs<T, S, R, I, W, B>(
    jobs: usize,
    init: I,
    work: W,
    body: B,
    obs: PoolObs,
) -> (R, Vec<String>)
where
    T: Send,
    I: Fn(usize) -> Result<S> + Sync,
    W: Fn(&mut S, TaskCtx, T) + Sync,
    B: FnOnce(&Service<T>) -> R,
{
    let jobs = jobs.max(1);
    let service = Service::new(jobs, obs);
    let out = std::thread::scope(|scope| {
        for w in 0..jobs {
            let service = &service;
            let init = &init;
            let work = &work;
            scope.spawn(move || {
                let mut state = match catch_unwind(AssertUnwindSafe(|| init(w))) {
                    Ok(Ok(s)) => s,
                    Ok(Err(e)) => {
                        service.init_errors.lock().unwrap()
                            .push(format!("worker {w}: {e}"));
                        service.worker_exit();
                        return;
                    }
                    Err(p) => {
                        service.init_errors.lock().unwrap().push(format!(
                            "worker {w}: init panicked: {}", panic_msg(p.as_ref())));
                        service.worker_exit();
                        return;
                    }
                };
                while let Some((i, item)) = service.pop() {
                    let ctx = TaskCtx { worker: w, index: i };
                    // a panicking item is consumed by the unwind (its drop
                    // reports through its completion channel); the worker
                    // itself survives to serve the next item
                    let t0 = service.obs.clock.now_ns();
                    let r = catch_unwind(AssertUnwindSafe(|| work(&mut state, ctx, item)));
                    if r.is_err() {
                        service.obs.panics.inc();
                    }
                    service
                        .obs
                        .busy(w)
                        .add(service.obs.clock.now_ns().saturating_sub(t0));
                }
                service.worker_exit();
            });
        }
        let body_result = catch_unwind(AssertUnwindSafe(|| body(&service)));
        // close even when body panicked, or the scope would join forever
        service.close();
        match body_result {
            Ok(r) => r,
            Err(p) => std::panic::resume_unwind(p),
        }
    });
    // all workers have joined: the init-error list is final
    let init_errors = service.init_errors.into_inner().unwrap();
    (out, init_errors)
}

// ------------------------------------------------------------- background ---

/// A named background thread with cooperative shutdown: `tick` runs once
/// immediately and then once per `interval` until the owner stops it.
/// Shutdown **joins** the thread (explicitly via
/// [`stop_and_join`](Background::stop_and_join), or implicitly on drop),
/// so a service that owns one — the serve spool watcher runs on a
/// `Background` — can never leak its poller past its own shutdown.
///
/// The interval sleep is sliced so stop latency stays bounded (~10ms)
/// even for long poll intervals.
pub struct Background {
    stop: std::sync::Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Background {
    pub fn spawn<F>(name: &str, interval: std::time::Duration, mut tick: F)
                    -> std::io::Result<Background>
    where
        F: FnMut() + Send + 'static,
    {
        let stop = std::sync::Arc::new(AtomicBool::new(false));
        let thread_stop = stop.clone();
        let handle = std::thread::Builder::new()
            .name(name.to_string())
            .spawn(move || {
                let slice = std::time::Duration::from_millis(10);
                while !thread_stop.load(Ordering::Relaxed) {
                    tick();
                    let mut remaining = interval;
                    while !thread_stop.load(Ordering::Relaxed)
                        && remaining > std::time::Duration::ZERO
                    {
                        let step = remaining.min(slice);
                        std::thread::sleep(step);
                        remaining = remaining.saturating_sub(step);
                    }
                }
            })?;
        Ok(Background { stop, handle: Some(handle) })
    }

    /// Signal the thread to stop and block until it has exited.
    pub fn stop_and_join(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Background {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Stateless convenience wrapper around [`run_stateful`].
pub fn run<T, R, W>(jobs: usize, items: Vec<T>, work: W) -> Vec<Result<R>>
where
    T: Send,
    R: Send,
    W: Fn(TaskCtx, T) -> Result<R> + Sync,
{
    run_stateful(jobs, items, |_| Ok(()), |_, ctx, item| work(ctx, item))
}

/// Collapse per-item results to the first error (by input index), or the
/// full ordered output vector.
pub fn collect_ordered<R>(results: Vec<Result<R>>) -> Result<Vec<R>> {
    let mut out = Vec::with_capacity(results.len());
    for r in results {
        out.push(r?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn results_are_in_input_order() {
        for jobs in [1, 2, 4, 8] {
            let items: Vec<usize> = (0..64).collect();
            let results = run(jobs, items, |_ctx, i| {
                // stagger so completion order differs from input order
                std::thread::sleep(Duration::from_micros(((i * 7) % 13) as u64));
                Ok(i * 2)
            });
            let vals = collect_ordered(results).unwrap();
            assert_eq!(vals, (0..64).map(|i| i * 2).collect::<Vec<_>>(), "jobs={jobs}");
        }
    }

    #[test]
    fn empty_input_returns_empty() {
        let results = run(4, Vec::<usize>::new(), |_ctx, i| Ok(i));
        assert!(results.is_empty());
    }

    #[test]
    fn more_jobs_than_items() {
        let results = run(16, vec![1usize, 2, 3], |_ctx, i| Ok(i + 10));
        assert_eq!(collect_ordered(results).unwrap(), vec![11, 12, 13]);
    }

    #[test]
    fn panic_surfaces_as_error_not_hang() {
        for jobs in [1, 4] {
            let results = run(jobs, (0..8).collect::<Vec<usize>>(), |_ctx, i| {
                if i == 3 {
                    panic!("boom at {i}");
                }
                Ok(i)
            });
            assert_eq!(results.len(), 8);
            let e = results[3].as_ref().unwrap_err().to_string();
            assert!(e.contains("panicked"), "{e}");
            assert!(e.contains("boom at 3"), "{e}");
            // fail-fast: other items either finished before the abort
            // (their own value) or were skipped with the cause embedded
            for (i, r) in results.iter().enumerate() {
                match r {
                    Ok(v) => assert_eq!(*v, i),
                    Err(e) if i == 3 => assert!(e.to_string().contains("panicked")),
                    Err(e) => {
                        let m = e.to_string();
                        assert!(m.contains("skipped"), "{m}");
                        assert!(m.contains("boom at 3"), "{m}");
                    }
                }
            }
            // whichever error index surfaces first, it names the root cause
            let surfaced = collect_ordered(results).unwrap_err().to_string();
            assert!(surfaced.contains("boom at 3"), "{surfaced}");
        }
    }

    #[test]
    fn fail_fast_skips_remaining_work_sequentially() {
        // jobs=1 is fully deterministic: everything after the failing
        // index is skipped, nothing before it is
        let results = run(1, (0..6).collect::<Vec<usize>>(), |_ctx, i| {
            if i == 2 {
                anyhow::bail!("item 2 refused");
            }
            Ok(i)
        });
        assert_eq!(*results[0].as_ref().unwrap(), 0);
        assert_eq!(*results[1].as_ref().unwrap(), 1);
        assert!(results[2].as_ref().unwrap_err().to_string().contains("refused"));
        for r in &results[3..] {
            let m = r.as_ref().unwrap_err().to_string();
            assert!(m.contains("skipped") && m.contains("refused"), "{m}");
        }
    }

    #[test]
    fn failed_init_items_are_stolen_by_survivors() {
        let results = run_stateful(
            2,
            (0..10).collect::<Vec<usize>>(),
            |w| {
                if w == 0 {
                    Err(anyhow!("worker 0 cannot start"))
                } else {
                    Ok(w)
                }
            },
            |state, _ctx, i| Ok(i + *state * 0),
        );
        assert_eq!(collect_ordered(results).unwrap(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn init_panic_is_contained_not_process_abort() {
        // one worker's init panics: its items are stolen, the pool
        // completes; all inits panicking degrades to per-item errors
        let results = run_stateful(
            2,
            (0..6).collect::<Vec<usize>>(),
            |w| {
                if w == 0 {
                    panic!("no device for worker {w}");
                }
                Ok(())
            },
            |_s, _ctx, i| Ok(i),
        );
        assert_eq!(collect_ordered(results).unwrap(), (0..6).collect::<Vec<_>>());

        let results = run_stateful(
            1,
            vec![1usize, 2],
            |_w| -> Result<()> { panic!("init always panics") },
            |_s, _ctx, i| Ok(i),
        );
        for r in &results {
            let m = r.as_ref().unwrap_err().to_string();
            assert!(m.contains("panicked"), "{m}");
        }
    }

    #[test]
    fn all_init_failures_error_every_item() {
        let results = run_stateful(
            3,
            (0..6).collect::<Vec<usize>>(),
            |w| -> Result<()> { Err(anyhow!("no runtime on worker {w}")) },
            |_state, _ctx, i| Ok(i),
        );
        assert_eq!(results.len(), 6);
        for r in &results {
            let e = r.as_ref().unwrap_err().to_string();
            assert!(e.contains("never executed"), "{e}");
            assert!(e.contains("no runtime"), "{e}");
        }
    }

    #[test]
    fn work_is_stolen_from_a_busy_worker() {
        // Handshake instead of a timing-dependent sleep: worker 0 blocks
        // inside each of its tasks until worker 1 has executed 6 tasks —
        // its own 5 dealt items plus at least one it could only have
        // STOLEN from worker 0's deque (worker 0 is parked, not done).
        let w1_count = AtomicUsize::new(0);
        let results = run_stateful(
            2,
            (0..10).collect::<Vec<usize>>(),
            |w| Ok(w),
            |me, _ctx, i| {
                if *me == 1 {
                    w1_count.fetch_add(1, Ordering::SeqCst);
                } else {
                    let t0 = std::time::Instant::now();
                    while w1_count.load(Ordering::SeqCst) < 6
                        && t0.elapsed() < Duration::from_secs(5)
                    {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
                Ok((*me, i))
            },
        );
        let pairs = collect_ordered(results).unwrap();
        assert_eq!(pairs.len(), 10);
        // every even index was dealt to worker 0's deque; at least one of
        // them must have been executed by worker 1 (stolen)
        let stolen = pairs.iter().filter(|(w, i)| *w == 1 && i % 2 == 0).count();
        assert!(stolen > 0, "no work was stolen: {pairs:?}");
    }

    /// A service item whose drop records whether it was ever processed —
    /// the completion-channel pattern the serve scheduler uses.
    struct Probe {
        id: usize,
        done: std::sync::Arc<Mutex<Vec<(usize, bool)>>>,
        processed: bool,
    }

    impl Drop for Probe {
        fn drop(&mut self) {
            self.done.lock().unwrap().push((self.id, self.processed));
        }
    }

    #[test]
    fn service_processes_all_items() {
        let done = std::sync::Arc::new(Mutex::new(Vec::new()));
        for jobs in [1, 4] {
            done.lock().unwrap().clear();
            let n = 32;
            let (out, errs) = run_service(
                jobs,
                |w| Ok(w),
                |_state, _ctx, mut item: Probe| {
                    item.processed = true;
                },
                |svc| {
                    for id in 0..n {
                        svc.push(Probe { id, done: done.clone(), processed: false });
                    }
                    n
                },
            );
            assert_eq!(out, n);
            assert!(errs.is_empty(), "{errs:?}");
            let d = done.lock().unwrap();
            assert_eq!(d.len(), n, "jobs={jobs}");
            assert!(d.iter().all(|&(_, p)| p), "unprocessed items: {d:?}");
        }
    }

    #[test]
    fn service_push_after_close_drops_item() {
        let done = std::sync::Arc::new(Mutex::new(Vec::new()));
        run_service(
            1,
            |w| Ok(w),
            |_s, _ctx, mut item: Probe| {
                item.processed = true;
            },
            |svc| {
                svc.close();
                svc.push(Probe { id: 7, done: done.clone(), processed: false });
            },
        );
        let d = done.lock().unwrap();
        assert_eq!(d.as_slice(), &[(7, false)], "{d:?}");
    }

    #[test]
    fn service_all_workers_dead_drains_queue() {
        // every init fails: pushed items must still be dropped (their
        // completion channels fire) rather than stranded forever
        let done = std::sync::Arc::new(Mutex::new(Vec::new()));
        let (_, errs) = run_service(
            2,
            |w| -> Result<()> { Err(anyhow!("worker {w} cannot start")) },
            |_s, _ctx, mut item: Probe| {
                item.processed = true;
            },
            |svc| {
                // workers may exit before or after these pushes; both
                // paths (dead-pool drop and strand-drain) end in a drop
                for id in 0..4 {
                    svc.push(Probe { id, done: done.clone(), processed: false });
                }
                let t0 = std::time::Instant::now();
                while done.lock().unwrap().len() < 4
                    && t0.elapsed() < Duration::from_secs(5)
                {
                    std::thread::sleep(Duration::from_millis(1));
                }
                assert_eq!(svc.init_errors().len(), 2);
            },
        );
        assert_eq!(errs.len(), 2, "{errs:?}");
        assert!(errs.iter().all(|e| e.contains("cannot start")), "{errs:?}");
        let d = done.lock().unwrap();
        assert_eq!(d.len(), 4, "{d:?}");
        assert!(d.iter().all(|&(_, p)| !p));
    }

    #[test]
    fn service_worker_panic_consumes_item_not_pool() {
        let done = std::sync::Arc::new(Mutex::new(Vec::new()));
        run_service(
            2,
            |w| Ok(w),
            |_s, _ctx, mut item: Probe| {
                if item.id == 1 {
                    panic!("boom on {}", item.id);
                }
                item.processed = true;
            },
            |svc| {
                for id in 0..8 {
                    svc.push(Probe { id, done: done.clone(), processed: false });
                }
            },
        );
        let d = done.lock().unwrap();
        assert_eq!(d.len(), 8);
        for &(id, p) in d.iter() {
            assert_eq!(p, id != 1, "item {id}");
        }
    }

    #[test]
    fn background_ticks_and_never_outlives_join() {
        let count = std::sync::Arc::new(AtomicUsize::new(0));
        let tick_count = count.clone();
        let bg = Background::spawn("test-bg", Duration::from_millis(1), move || {
            tick_count.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        let t0 = std::time::Instant::now();
        while count.load(Ordering::SeqCst) < 3 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(count.load(Ordering::SeqCst) >= 3, "watcher never ticked");
        bg.stop_and_join();
        // joined means stopped: no tick can land after stop_and_join
        let after = count.load(Ordering::SeqCst);
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(count.load(Ordering::SeqCst), after, "ticked after join");
    }

    #[test]
    fn pool_obs_counts_busy_time_and_panics() {
        let reg = MetricsRegistry::new(false);
        let obs = PoolObs::register(&reg, "test", 2);
        let results = run_stateful_obs(
            2,
            (0..16).collect::<Vec<usize>>(),
            |w| Ok(w),
            |_s, _ctx, i| {
                std::thread::sleep(Duration::from_micros(50));
                Ok(i)
            },
            &obs,
        );
        assert!(collect_ordered(results).is_ok());
        let total_busy: u64 = (0..2).map(|w| obs.busy_ns(w)).sum();
        assert!(total_busy > 0, "no busy time recorded");
        assert_eq!(obs.panics(), 0);

        let obs2 = PoolObs::register(&reg, "test_panics", 1);
        let r = run_stateful_obs(
            1,
            vec![0usize],
            |_| Ok(()),
            |_s, _c, _i| -> Result<usize> { panic!("counted") },
            &obs2,
        );
        assert!(r[0].is_err());
        assert_eq!(obs2.panics(), 1);
        // re-registering the same pool shares the counters
        assert_eq!(PoolObs::register(&reg, "test_panics", 1).panics(), 1);
    }

    #[test]
    fn service_obs_counts_parks() {
        let reg = MetricsRegistry::new(false);
        let obs = PoolObs::register(&reg, "svc", 1);
        let watcher = obs.clone();
        let done = std::sync::Arc::new(Mutex::new(Vec::new()));
        run_service_obs(
            1,
            |w| Ok(w),
            |_s, _c, mut item: Probe| {
                item.processed = true;
            },
            |svc| {
                svc.push(Probe { id: 0, done: done.clone(), processed: false });
                // the worker parks whenever it finds the queue empty and
                // open — before the push, or right after draining it
                let t0 = std::time::Instant::now();
                while watcher.parks() < 1 && t0.elapsed() < Duration::from_secs(5) {
                    std::thread::sleep(Duration::from_millis(1));
                }
            },
            obs.clone(),
        );
        assert!(obs.parks() >= 1, "worker never parked");
    }

    #[test]
    fn worker_state_is_initialized_once_per_worker() {
        let inits = AtomicUsize::new(0);
        let results = run_stateful(
            4,
            (0..32).collect::<Vec<usize>>(),
            |w| {
                inits.fetch_add(1, Ordering::SeqCst);
                Ok(w)
            },
            |_state, _ctx, i| Ok(i),
        );
        assert!(collect_ordered(results).is_ok());
        assert!(inits.load(Ordering::SeqCst) <= 4);
    }
}
