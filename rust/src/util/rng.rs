//! Seeded, reproducible RNG (splitmix64 + xoshiro256**) — every data
//! generator and experiment takes an explicit seed so runs are exactly
//! repeatable across machines (the paper's 5-seed protocol, §5.1).

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm),
                  splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (for per-task / per-seed forks).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..8).map({ let mut r = Rng::new(42); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = Rng::new(42); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map({ let mut r = Rng::new(43); move |_| r.next_u64() }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let k = r.range(3, 9);
            assert!((3..9).contains(&k));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
