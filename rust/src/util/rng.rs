//! Seeded, reproducible RNG (splitmix64 + xoshiro256**) — every data
//! generator and experiment takes an explicit seed so runs are exactly
//! repeatable across machines (the paper's 5-seed protocol, §5.1).

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm),
                  splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (for per-task / per-seed forks).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n), by integer rejection sampling on
    /// [`next_u64`](Self::next_u64) — every residue exactly equally
    /// likely. (The old float path `(f64() * n) as usize % n` doubled
    /// rank 0's probability at the rounding edge — `f64() * n` can round
    /// up to exactly `n`, which `% n` folds back onto 0 — and had
    /// resolution bias for n beyond the 53-bit float grid.)
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n64 = n as u64;
        if n64.is_power_of_two() {
            return (self.next_u64() & (n64 - 1)) as usize;
        }
        // accept draws below the largest multiple of n, so the fold to
        // [0, n) is exact; rejection probability < 2^-11 for n < 2^53,
        // expected draws < 2 always
        let zone = u64::MAX - u64::MAX % n64;
        loop {
            let x = self.next_u64();
            if x < zone {
                return (x % n64) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..8).map({ let mut r = Rng::new(42); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = Rng::new(42); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map({ let mut r = Rng::new(43); move |_| r.next_u64() }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let k = r.range(3, 9);
            assert!((3..9).contains(&k));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_is_exact_and_unbiased() {
        // power-of-two path is a pure mask of next_u64
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for _ in 0..256 {
            assert_eq!(a.below(8), (b.next_u64() & 7) as usize);
        }
        // non-power-of-two: in range, and every residue reachable
        let mut r = Rng::new(10);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            // 5 bins x 10k expected; ±6% is > 8 sigma
            assert!((9_400..10_600).contains(&c), "{counts:?}");
        }
        // the old float path could round (f64() * n) up to n and fold it
        // onto 0; the integer path stays in range even for huge n where
        // f64 resolution ran out
        let huge = (1usize << 62) + 3;
        for _ in 0..64 {
            assert!(r.below(huge) < huge);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
