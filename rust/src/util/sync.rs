//! Poison-tolerant lock acquisition for the serving fleet.
//!
//! `std`'s mutexes poison when a holder panics, and every subsequent
//! `.lock().unwrap()` on the same lock then panics too — one crashed
//! worker cascades through every thread that shares a registry,
//! metrics sink, or batcher with it. The serving tier prefers fleet
//! survival: the panicking request already failed (its `ResponseSlot`
//! reports `dropped unserved`), and every structure guarded by these
//! locks is either append-only (latency vectors, counters) or
//! validated on read (registry slots hold completed `Arc` swaps), so
//! the data a panicking holder leaves behind is safe to keep serving.
//!
//! `lock_or_recover` and friends therefore treat poison as a
//! recoverable condition: they return the guard either way. Callers
//! that genuinely need mid-mutation atomicity must not use these
//! helpers — hold the invariant with a commit-last write (the
//! registry's `Arc` swap idiom) instead.
//!
//! The `lock-discipline` lint (`repro analyze`) flags any remaining
//! `.lock().unwrap()` / `.read().unwrap()` / `.write().unwrap()` in
//! `serve/` and `store/` and points here.
//!
//! The `_observed` variants add contention profiling on top of poison
//! recovery: each acquisition records its wait time into a per-site
//! histogram and bumps per-site acquire/poison-recovery counters on a
//! [`LockObs`] handle. The handle is `Arc`-cheap and defaults to
//! detached ([`LockObs::disabled`]), so instrumented call sites are
//! unconditional — no `Option` branching on the hot path. Wait times
//! come from the registry's [`SpanClock`], which is logical under fifo
//! mode, so instrumentation never reads the wall clock on the
//! deterministic path; all `lock_*` metrics are
//! [`Class::Volatile`](crate::obs::metrics::Class) (contention is
//! scheduling-dependent by nature) and therefore excluded from
//! deterministic exports.

use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

use crate::obs::hist::Hist;
use crate::obs::metrics::{detached_hist, Class, Counter, MetricsRegistry};
use crate::obs::span::SpanClock;

/// Lock a mutex, recovering the guard from a poisoned lock instead of
/// panicking. See the module docs for when this is sound.
pub fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Read-lock an `RwLock`, recovering from poison.
pub fn read_or_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    match l.read() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Write-lock an `RwLock`, recovering from poison.
pub fn write_or_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    match l.write() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Block on a condvar, recovering the re-acquired guard from poison.
/// The wakeup protocol (re-check the predicate in a loop) is unchanged;
/// only the poison propagation is swallowed.
pub fn wait_or_recover<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(g) {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Per-lock-site contention handles: wait-time histogram plus
/// acquire/poison-recovery counters, labeled `site=<name>`.
#[derive(Clone, Debug)]
pub struct LockObs {
    clock: Arc<SpanClock>,
    wait_ns: Arc<Hist>,
    acquires: Arc<Counter>,
    poisons: Arc<Counter>,
}

impl LockObs {
    /// Register the lock site's metrics on `reg`. Re-registering the
    /// same site returns handles onto the same metrics.
    pub fn register(reg: &MetricsRegistry, site: &str) -> LockObs {
        LockObs {
            clock: reg.clock(),
            wait_ns: reg.hist("lock_wait_ns", &[("site", site)], Class::Volatile),
            acquires: reg
                .counter("lock_acquires_total", &[("site", site)], Class::Volatile),
            poisons: reg.counter(
                "lock_poison_recoveries_total",
                &[("site", site)],
                Class::Volatile,
            ),
        }
    }

    /// Detached handles (no registry): instrumented code runs
    /// identically, nothing is exported.
    pub fn disabled() -> LockObs {
        LockObs {
            clock: Arc::new(SpanClock::new(true)),
            wait_ns: detached_hist(),
            acquires: Counter::detached(),
            poisons: Counter::detached(),
        }
    }

    pub fn acquires(&self) -> u64 {
        self.acquires.get()
    }

    pub fn poisons(&self) -> u64 {
        self.poisons.get()
    }
}

/// [`lock_or_recover`] plus contention accounting on `obs`.
pub fn lock_observed<'a, T>(obs: &LockObs, m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    let start = obs.clock.now_ns();
    let res = m.lock();
    obs.wait_ns.record(obs.clock.now_ns().saturating_sub(start));
    obs.acquires.inc();
    match res {
        Ok(g) => g,
        Err(poisoned) => {
            obs.poisons.inc();
            poisoned.into_inner()
        }
    }
}

/// [`read_or_recover`] plus contention accounting on `obs`.
pub fn read_observed<'a, T>(obs: &LockObs, l: &'a RwLock<T>) -> RwLockReadGuard<'a, T> {
    let start = obs.clock.now_ns();
    let res = l.read();
    obs.wait_ns.record(obs.clock.now_ns().saturating_sub(start));
    obs.acquires.inc();
    match res {
        Ok(g) => g,
        Err(poisoned) => {
            obs.poisons.inc();
            poisoned.into_inner()
        }
    }
}

/// [`write_or_recover`] plus contention accounting on `obs`.
pub fn write_observed<'a, T>(obs: &LockObs, l: &'a RwLock<T>) -> RwLockWriteGuard<'a, T> {
    let start = obs.clock.now_ns();
    let res = l.write();
    obs.wait_ns.record(obs.clock.now_ns().saturating_sub(start));
    obs.acquires.inc();
    match res {
        Ok(g) => g,
        Err(poisoned) => {
            obs.poisons.inc();
            poisoned.into_inner()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex, RwLock};

    #[test]
    fn mutex_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        let mut g = lock_or_recover(&m);
        assert_eq!(*g, 7);
        *g += 1;
        drop(g);
        assert_eq!(*lock_or_recover(&m), 8);
    }

    #[test]
    fn rwlock_recovers_from_poison() {
        let l = Arc::new(RwLock::new(3usize));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison the rwlock");
        })
        .join();
        assert_eq!(*read_or_recover(&l), 3);
        *write_or_recover(&l) = 4;
        assert_eq!(*read_or_recover(&l), 4);
    }

    #[test]
    fn condvar_wait_returns_guard() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let waker = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            *lock_or_recover(m) = true;
            cv.notify_all();
        });
        let (m, cv) = &*pair;
        let mut ready = lock_or_recover(m);
        while !*ready {
            ready = wait_or_recover(cv, ready);
        }
        assert!(*ready);
        waker.join().unwrap();
    }

    #[test]
    fn observed_lock_counts_acquires_and_poison_recoveries() {
        let reg = MetricsRegistry::new(false);
        let obs = LockObs::register(&reg, "test_site");
        let m = Arc::new(Mutex::new(1usize));
        *lock_observed(&obs, &m) += 1;
        assert_eq!(obs.acquires(), 1);
        assert_eq!(obs.poisons(), 0);
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert_eq!(*lock_observed(&obs, &m), 2);
        assert_eq!(obs.acquires(), 2);
        assert_eq!(obs.poisons(), 1);
        // same site re-registered shares the same counters
        let again = LockObs::register(&reg, "test_site");
        assert_eq!(again.acquires(), 2);
    }

    #[test]
    fn observed_rwlock_records_both_modes() {
        let reg = MetricsRegistry::new(true);
        let obs = LockObs::register(&reg, "rw_site");
        let l = RwLock::new(5usize);
        assert_eq!(*read_observed(&obs, &l), 5);
        *write_observed(&obs, &l) = 6;
        assert_eq!(*read_observed(&obs, &l), 6);
        assert_eq!(obs.acquires(), 3);
        // disabled handles run the same path without a registry
        let off = LockObs::disabled();
        assert_eq!(*read_observed(&off, &l), 6);
        assert_eq!(off.acquires(), 1);
    }
}
