//! Poison-tolerant lock acquisition for the serving fleet.
//!
//! `std`'s mutexes poison when a holder panics, and every subsequent
//! `.lock().unwrap()` on the same lock then panics too — one crashed
//! worker cascades through every thread that shares a registry,
//! metrics sink, or batcher with it. The serving tier prefers fleet
//! survival: the panicking request already failed (its `ResponseSlot`
//! reports `dropped unserved`), and every structure guarded by these
//! locks is either append-only (latency vectors, counters) or
//! validated on read (registry slots hold completed `Arc` swaps), so
//! the data a panicking holder leaves behind is safe to keep serving.
//!
//! `lock_or_recover` and friends therefore treat poison as a
//! recoverable condition: they return the guard either way. Callers
//! that genuinely need mid-mutation atomicity must not use these
//! helpers — hold the invariant with a commit-last write (the
//! registry's `Arc` swap idiom) instead.
//!
//! The `lock-discipline` lint (`repro analyze`) flags any remaining
//! `.lock().unwrap()` / `.read().unwrap()` / `.write().unwrap()` in
//! `serve/` and `store/` and points here.

use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock a mutex, recovering the guard from a poisoned lock instead of
/// panicking. See the module docs for when this is sound.
pub fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Read-lock an `RwLock`, recovering from poison.
pub fn read_or_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    match l.read() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Write-lock an `RwLock`, recovering from poison.
pub fn write_or_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    match l.write() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Block on a condvar, recovering the re-acquired guard from poison.
/// The wakeup protocol (re-check the predicate in a loop) is unchanged;
/// only the poison propagation is swallowed.
pub fn wait_or_recover<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(g) {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex, RwLock};

    #[test]
    fn mutex_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        let mut g = lock_or_recover(&m);
        assert_eq!(*g, 7);
        *g += 1;
        drop(g);
        assert_eq!(*lock_or_recover(&m), 8);
    }

    #[test]
    fn rwlock_recovers_from_poison() {
        let l = Arc::new(RwLock::new(3usize));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("poison the rwlock");
        })
        .join();
        assert_eq!(*read_or_recover(&l), 3);
        *write_or_recover(&l) = 4;
        assert_eq!(*read_or_recover(&l), 4);
    }

    #[test]
    fn condvar_wait_returns_guard() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let waker = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            *lock_or_recover(m) = true;
            cv.notify_all();
        });
        let (m, cv) = &*pair;
        let mut ready = lock_or_recover(m);
        while !*ready {
            ready = wait_or_recover(cv, ready);
        }
        assert!(*ready);
        waker.join().unwrap();
    }
}
