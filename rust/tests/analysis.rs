//! Integration tests for the `repro analyze` static-analysis pass.
//!
//! Two halves:
//!   1. Fixture expectations — every lint has positive / allowed / clean
//!      fixtures under `tests/analysis_fixtures/`, and each positive
//!      fixture asserts the exact `(lint, line)` set so a lexer or
//!      scanner regression shows up as a precise diff.
//!   2. The self-run — the crate's own `src/`, `benches/` and `tests/`
//!      trees (this fixture corpus excluded) must be clean: zero
//!      unsuppressed findings, and every suppression carries a reason.
//!      This is the same gate CI runs via `repro analyze`.

use std::path::{Path, PathBuf};

use quantum_peft::analysis::{self, LINT_NAMES};
use quantum_peft::util::json::Json;

fn fixture_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/analysis_fixtures")
}

/// Analyze one fixture, passing a *relative* rel path so scope
/// classification does not depend on where the checkout lives.
fn analyze_fixture(rel: &str) -> (Vec<(String, u32)>, usize) {
    let path = fixture_root().join(rel);
    let source = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let (findings, suppressed) =
        analysis::analyze_source(&format!("tests/analysis_fixtures/{rel}"), &source);
    let pairs = findings.iter().map(|f| (f.lint.to_string(), f.line)).collect();
    (pairs, suppressed.len())
}

/// Assert a fixture produces exactly `lines` findings of one `lint`
/// (in source order) and `suppressed` reasoned allows.
fn expect(rel: &str, lint: &str, lines: &[u32], suppressed: usize) {
    let (got, sup) = analyze_fixture(rel);
    let want: Vec<(String, u32)> =
        lines.iter().map(|l| (lint.to_string(), *l)).collect();
    assert_eq!(got, want, "findings for {rel}");
    assert_eq!(sup, suppressed, "suppressed count for {rel}");
}

// ------------------------------------------------------------- determinism

#[test]
fn determinism_positive() {
    // for-in @11, .keys() @18, .retain() @19, .iter() @26, the two
    // clocks @30/@31 — which serve/ scope also reports under
    // obs-discipline; the #[cfg(test)] block at the bottom is exempt.
    let (got, sup) = analyze_fixture("serve/det_positive.rs");
    let want: Vec<(String, u32)> = [
        ("determinism", 11),
        ("determinism", 18),
        ("determinism", 19),
        ("determinism", 26),
        ("determinism", 30),
        ("determinism", 31),
        ("obs-discipline", 30),
        ("obs-discipline", 31),
    ]
    .iter()
    .map(|(l, n)| (l.to_string(), *n))
    .collect();
    assert_eq!(got, want, "findings for serve/det_positive.rs");
    assert_eq!(sup, 0);
}

#[test]
fn determinism_allowed() {
    // One allow on the line above, one trailing on the same line; each
    // names both clock lints, so each suppresses two findings.
    expect("serve/det_allowed.rs", "determinism", &[], 4);
}

#[test]
fn determinism_clean() {
    expect("serve/det_clean.rs", "determinism", &[], 0);
}

// --------------------------------------------------------- lock-discipline

#[test]
fn lock_positive() {
    // unwrap @10, expect @14, unwraps @18/@19, plus the undeclared
    // nested-hold reported at the second held acquisition (@19).
    expect("serve/lock_positive.rs", "lock-discipline", &[10, 14, 18, 19, 19], 0);
}

#[test]
fn lock_allowed() {
    expect("serve/lock_allowed.rs", "lock-discipline", &[], 1);
}

#[test]
fn lock_clean() {
    expect("serve/lock_clean.rs", "lock-discipline", &[], 0);
}

#[test]
fn lock_order_inversion() {
    // The fixture path ends in serve/registry.rs, so the declared order
    // applies: `inner` acquired (@27) while `tenants` (@26) is held.
    let rel = "serve/registry.rs";
    let path = fixture_root().join(rel);
    let source = std::fs::read_to_string(&path).expect("read registry fixture");
    let (findings, suppressed) =
        analysis::analyze_source(&format!("tests/analysis_fixtures/{rel}"), &source);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].lint, "lock-discipline");
    assert_eq!(findings[0].line, 27);
    assert!(
        findings[0].message.contains("declared"),
        "inversion message should point at the declared table: {}",
        findings[0].message
    );
    assert!(suppressed.is_empty());
}

// -------------------------------------------------------------- panic-path

#[test]
fn panic_positive() {
    // v[0] @3, .unwrap @7, .expect @11, panic! @16, unreachable! @18.
    expect("store/panic_positive.rs", "panic-path", &[3, 7, 11, 16, 18], 0);
}

#[test]
fn panic_allowed() {
    expect("store/panic_allowed.rs", "panic-path", &[], 1);
}

#[test]
fn panic_clean() {
    expect("store/panic_clean.rs", "panic-path", &[], 0);
}

// ----------------------------------------------------------- framing-casts

#[test]
fn framing_positive() {
    // `as u16` @4, two `as usize` @8, `as u32` @12.
    expect("store/wal.rs", "framing-casts", &[4, 8, 8, 12], 0);
}

#[test]
fn framing_allowed() {
    expect("store/snapshot.rs", "framing-casts", &[], 1);
}

#[test]
fn framing_clean() {
    expect("store/recover.rs", "framing-casts", &[], 0);
}

// ---------------------------------------------------------- log-discipline

#[test]
fn log_positive() {
    expect("metrics/log_positive.rs", "log-discipline", &[3, 4], 0);
}

#[test]
fn log_allowed() {
    expect("metrics/log_allowed.rs", "log-discipline", &[], 1);
}

#[test]
fn log_clean() {
    expect("metrics/log_clean.rs", "log-discipline", &[], 0);
}

// ----------------------------------------------------------- io-durability

#[test]
fn io_positive() {
    // File::create @6 and fs::write @11, neither fn has an fsync.
    expect("store/io_positive.rs", "io-durability", &[6, 11], 0);
}

#[test]
fn io_allowed() {
    expect("store/io_allowed.rs", "io-durability", &[], 1);
}

#[test]
fn io_clean() {
    expect("store/io_clean.rs", "io-durability", &[], 0);
}

// ---------------------------------------------------------- obs-discipline

#[test]
fn obs_positive() {
    // Instant::now @6 and SystemTime::now @11 inside obs/ — outside the
    // determinism scope, so each is exactly one obs-discipline finding.
    expect("obs/positive.rs", "obs-discipline", &[6, 11], 0);
}

#[test]
fn obs_allowed() {
    expect("obs/allowed.rs", "obs-discipline", &[], 1);
}

#[test]
fn obs_clean() {
    expect("obs/clean.rs", "obs-discipline", &[], 0);
}

// ------------------------------------------------------------- suppression

#[test]
fn suppression_bare_allow_is_a_finding() {
    expect("serve/suppress_bare.rs", "suppression", &[3], 0);
}

#[test]
fn suppression_unknown_lint_is_a_finding() {
    expect("serve/suppress_unknown.rs", "suppression", &[2], 0);
}

#[test]
fn suppression_malformed_directive_is_a_finding() {
    expect("serve/suppress_malformed.rs", "suppression", &[2], 0);
}

// ---------------------------------------------------- lock-order-transitive

#[test]
fn xlock_positive() {
    // The call reaching `registry` while `store` (its successor in
    // GLOBAL_ORDER) is held @23, and the call re-acquiring the held
    // `cfg` @29 — both attributed to the call site, not the callee.
    expect("serve/xlock_positive.rs", "lock-order-transitive", &[23, 29], 0);
}

#[test]
fn xlock_allowed() {
    expect("serve/xlock_allowed.rs", "lock-order-transitive", &[], 1);
}

#[test]
fn xlock_clean() {
    expect("serve/xlock_clean.rs", "lock-order-transitive", &[], 0);
}

#[test]
fn cross_file_lock_inversion_attributes_the_call_site() {
    let read = |rel: &str| {
        let path = fixture_root().join(rel);
        std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
    };
    let files = vec![
        (
            "tests/analysis_fixtures/serve/xinv_router.rs".to_string(),
            read("serve/xinv_router.rs"),
        ),
        (
            "tests/analysis_fixtures/serve/xinv_table.rs".to_string(),
            read("serve/xinv_table.rs"),
        ),
    ];
    let report = analysis::analyze_sources(&files);
    assert_eq!(report.findings.len(), 1, "{:?}", report.findings);
    let f = &report.findings[0];
    assert_eq!(f.lint, "lock-order-transitive");
    assert_eq!(f.file, "tests/analysis_fixtures/serve/xinv_router.rs");
    assert_eq!(f.line, 13, "attributed to the caller's call site");
    assert!(f.message.contains("refresh_routes"), "{}", f.message);
    assert!(f.message.contains("xinv_table.rs:11"), "names the reached acquisition: {}", f.message);
    assert!(report.suppressed.is_empty());
}

#[test]
fn cross_file_halves_are_silent_alone() {
    // The callee never nests holds; the caller cannot see the reached
    // acquisition without the callee's file in the analyzed set.
    expect("serve/xinv_router.rs", "lock-order-transitive", &[], 0);
    expect("serve/xinv_table.rs", "lock-order-transitive", &[], 0);
}

// ------------------------------------------------------ metrics-discipline

#[test]
fn metrics_positive() {
    // Computed name @7 and non-snake_case literal @8 in scan order,
    // then the duplicate registration of `fx_demo_total` reported at
    // its second site @9 (duplicates are appended after the scan).
    expect("obs/metrics_positive.rs", "metrics-discipline", &[7, 8, 9], 0);
}

#[test]
fn metrics_allowed() {
    expect("obs/metrics_allowed.rs", "metrics-discipline", &[], 1);
}

#[test]
fn metrics_clean() {
    expect("obs/metrics_clean.rs", "metrics-discipline", &[], 0);
}

// ------------------------------------------------------ blocking-under-lock

#[test]
fn blocking_positive() {
    // The direct fsync @17 and the bulk write reached through
    // `flush_segment` @18, both while the `wal` guard is held.
    expect("store/blocking_positive.rs", "blocking-under-lock", &[17, 18], 0);
}

#[test]
fn blocking_allowed() {
    expect("store/blocking_allowed.rs", "blocking-under-lock", &[], 1);
}

#[test]
fn blocking_clean() {
    expect("store/blocking_clean.rs", "blocking-under-lock", &[], 0);
}

// ------------------------------------------------------- atomics-discipline

#[test]
fn atomics_positive() {
    // Relaxed load @13 (spawned side) and store @14 (main side) on the
    // crossing `stop` flag; compare_exchange_weak with no retry loop @19.
    expect("serve/atomics_positive.rs", "atomics-discipline", &[13, 14, 19], 0);
}

#[test]
fn atomics_allowed() {
    expect("serve/atomics_allowed.rs", "atomics-discipline", &[], 1);
}

#[test]
fn atomics_clean() {
    expect("serve/atomics_clean.rs", "atomics-discipline", &[], 0);
}

// ------------------------------------------------------------ resource-leak

#[test]
fn leak_positive() {
    // Discarded thread handle @7, named-but-never-joined handle @11,
    // Background handle dropped at the spawn statement @15.
    expect("serve/leak_positive.rs", "resource-leak", &[7, 11, 15], 0);
}

#[test]
fn leak_allowed() {
    expect("serve/leak_allowed.rs", "resource-leak", &[], 1);
}

#[test]
fn leak_clean() {
    expect("serve/leak_clean.rs", "resource-leak", &[], 0);
}

// ---------------------------------------------------------- corpus totals

#[test]
fn fixture_corpus_totals() {
    let report = analysis::analyze_paths(&[fixture_root()]).expect("walk fixtures");
    assert_eq!(report.files_scanned, 42, "fixture .rs file count");
    // 46 = the 32 intra-file findings plus 11 interprocedural ones (the
    // xlock inversion + re-entrancy pair, the cross-file xinv_* case —
    // the corpus run sees both halves — two blocking-under-lock, three
    // atomics-discipline and three resource-leak) plus the three
    // metrics-discipline findings from obs/metrics_positive.rs.
    assert_eq!(report.findings.len(), 46, "total findings across corpus");
    assert_eq!(report.suppressed.len(), 15, "total reasoned allows");
    for s in &report.suppressed {
        assert!(
            !s.reason.is_empty(),
            "suppression without a reason at {}:{}",
            s.finding.file,
            s.finding.line
        );
    }
    // Every lint is exercised by at least one positive fixture.
    let hit: Vec<&str> = analysis::counts(&report).into_iter().map(|(l, _)| l).collect();
    for lint in LINT_NAMES {
        assert!(hit.contains(lint), "no fixture exercises lint `{lint}`");
    }
}

#[test]
fn json_output_schema() {
    let report = analysis::analyze_paths(&[fixture_root()]).expect("walk fixtures");
    let rendered = analysis::render_json(&report);
    let v = Json::parse(&rendered).expect("render_json emits valid json");
    assert_eq!(v.get("version").unwrap().as_usize().unwrap(), 1);
    assert_eq!(v.get("files_scanned").unwrap().as_usize().unwrap(), 42);
    let findings = v.get("findings").unwrap().as_arr().unwrap();
    assert_eq!(findings.len(), 46);
    for f in findings {
        let lint = f.get("lint").unwrap().as_str().unwrap();
        assert!(LINT_NAMES.contains(&lint), "unknown lint in json: {lint}");
        assert!(!f.get("file").unwrap().as_str().unwrap().is_empty());
        assert!(f.get("line").unwrap().as_usize().unwrap() >= 1);
        assert!(!f.get("message").unwrap().as_str().unwrap().is_empty());
    }
    let suppressed = v.get("suppressed").unwrap().as_arr().unwrap();
    assert_eq!(suppressed.len(), 15);
    for s in suppressed {
        assert!(
            !s.get("reason").unwrap().as_str().unwrap().is_empty(),
            "suppressed entry without a reason in json output"
        );
    }
    let counts = v.get("counts").unwrap().as_obj().unwrap();
    assert_eq!(counts.get("lock-discipline").unwrap().as_usize().unwrap(), 6);
    assert_eq!(counts.get("determinism").unwrap().as_usize().unwrap(), 6);
    assert_eq!(counts.get("obs-discipline").unwrap().as_usize().unwrap(), 4);
    assert_eq!(counts.get("lock-order-transitive").unwrap().as_usize().unwrap(), 3);
    assert_eq!(counts.get("blocking-under-lock").unwrap().as_usize().unwrap(), 2);
    assert_eq!(counts.get("atomics-discipline").unwrap().as_usize().unwrap(), 3);
    assert_eq!(counts.get("resource-leak").unwrap().as_usize().unwrap(), 3);
    assert_eq!(counts.get("metrics-discipline").unwrap().as_usize().unwrap(), 3);
}

// ---------------------------------------------------------------- self-run

/// The gate CI enforces: the crate's own source tree — `src/`, plus
/// `benches/` and `tests/` (this fixture corpus is excluded by the
/// directory walk), all analyzed as ONE crate so bench/test helpers
/// participate in the call graph exactly as `repro analyze` sees them
/// — has zero unsuppressed findings. On failure, print the same text
/// report a `repro analyze` run would.
#[test]
fn src_tree_is_clean() {
    // Integration tests run with cwd = the package root (rust/), but
    // fall back to the manifest dir so the test is cwd-independent.
    let roots: Vec<PathBuf> = ["src", "benches", "tests"]
        .iter()
        .map(|r| {
            let p = Path::new(r);
            if p.is_dir() {
                p.to_path_buf()
            } else {
                Path::new(env!("CARGO_MANIFEST_DIR")).join(r)
            }
        })
        .collect();
    let report = analysis::analyze_paths(&roots).expect("walk src/ + benches/ + tests/");
    assert!(report.files_scanned > 30, "scanned only {} files", report.files_scanned);
    assert!(
        report.clean(),
        "`repro analyze` would fail with {} finding(s):\n\n{}",
        report.findings.len(),
        analysis::render_text(&report)
    );
    for s in &report.suppressed {
        assert!(
            !s.reason.is_empty(),
            "suppression without a reason at {}:{}",
            s.finding.file,
            s.finding.line
        );
    }
}
