// log-discipline fixture: a reasoned allow on an explicit debug hook.
fn debug_dump(x: u64) {
    // analyze: allow(log-discipline) explicit debug hook behind a CLI flag
    println!("x = {x}");
}
