// log-discipline fixture: rendering into a String produces nothing.
use std::fmt::Write;

fn render(x: u64) -> String {
    let mut out = String::new();
    let _ = write!(out, "x = {x}");
    out
}
