// log-discipline fixture: stdout writes in a library module.
fn report(x: u64) {
    println!("x = {x}");
    eprintln!("warn");
}
