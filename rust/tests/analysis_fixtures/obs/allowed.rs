// obs-discipline fixture: the same read, suppressed with a reason.
use std::time::Instant;

fn wall_budget() -> f64 {
    // analyze: allow(obs-discipline) wall-clock budget guard; never shapes a latency or a line
    let t = Instant::now();
    t.elapsed().as_secs_f64()
}
