// obs-discipline fixture: idiomatic observability code takes its
// timestamps from an injected span clock, never from the wall.
pub struct SpanClockRef<'a> {
    now_ns: &'a dyn Fn() -> u64,
}

pub fn measure(clock: &SpanClockRef<'_>) -> u64 {
    let start = (clock.now_ns)();
    let end = (clock.now_ns)();
    end.saturating_sub(start)
}
