// metrics-discipline fixture: a computed name, suppressed with a
// reason.

fn fx_metrics_register_allowed(reg: &MetricsRegistry, shard: usize) {
    // analyze: allow(metrics-discipline) per-shard debug registry; the name family is documented in obs/mod.rs
    let c = reg.counter(&format!("fx_shard_{shard}_total"), &[], Class::Volatile);
    let _ = c;
}
