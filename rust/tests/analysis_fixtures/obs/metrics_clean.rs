// metrics-discipline fixture: snake_case literals, each registered at
// exactly one site.

fn fx_metrics_register_clean(reg: &MetricsRegistry) {
    let c = reg.counter("fx_clean_total", &[], Class::Stable);
    let g = reg.gauge("fx_clean_depth", &[], Class::Volatile);
    let h = reg.hist("fx_clean_ns", &[], Class::Volatile);
    let _ = (c, g, h);
}
