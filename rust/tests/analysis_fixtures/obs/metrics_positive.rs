// metrics-discipline fixture: a computed name, a non-snake_case
// literal, and a duplicate registration. The duplicate is reported at
// the second site, after the scan-order findings.

fn fx_metrics_register_positive(reg: &MetricsRegistry, which: &str) {
    let ok = reg.counter("fx_demo_total", &[], Class::Stable);
    let computed = reg.hist(&format!("fx_{which}_ns"), &[], Class::Volatile);
    let shouting = reg.gauge("FxQueueDepth", &[], Class::Volatile);
    let dup = reg.counter("fx_demo_total", &[], Class::Stable);
    let _ = (ok, computed, shouting, dup);
}
