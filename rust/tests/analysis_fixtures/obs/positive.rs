// obs-discipline fixture: raw clock reads inside the observability
// tree itself — only obs/span.rs (the SpanClock) may touch the wall.
use std::time::{Instant, SystemTime};

fn stamp() -> u64 {
    let t = Instant::now();
    t.elapsed().as_nanos() as u64
}

fn epoch() -> std::time::SystemTime {
    SystemTime::now()
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        let t = std::time::Instant::now();
        assert!(t.elapsed().as_secs() < 1);
    }
}
