// atomics-discipline fixture: the same crossing flag, with its one
// Relaxed side suppressed by the reason that names the real edge.
use std::sync::atomic::{AtomicBool, Ordering};
use std::thread;

struct V {
    halt: AtomicBool,
}

fn run_once(v: &'static V) {
    let h = thread::spawn(move || while !v.halt.load(Ordering::Acquire) {});
    // analyze: allow(atomics-discipline) the join below is the happens-before edge
    v.halt.store(true, Ordering::Relaxed);
    let _ = h.join();
}
