// atomics-discipline fixture: Release/Acquire across the spawn, and
// the weak compare-exchange inside its retry loop — nothing to report.
use std::sync::atomic::{AtomicBool, Ordering};
use std::thread;

struct U {
    quit: AtomicBool,
}

fn run_clean(u: &'static U) {
    let h = thread::spawn(move || while !u.quit.load(Ordering::Acquire) {});
    u.quit.store(true, Ordering::Release);
    let _ = h.join();
}

fn acquire_slot(u: &U) {
    while u
        .quit
        .compare_exchange_weak(false, true, Ordering::AcqRel, Ordering::Acquire)
        .is_err()
    {}
}
