// atomics-discipline fixture: a Relaxed store/load pair on an
// AtomicBool that crosses the spawn boundary (no happens-before
// edge), and a compare_exchange_weak outside any retry loop.
use std::sync::atomic::{AtomicBool, Ordering};
use std::thread;

struct W {
    stop: AtomicBool,
    ready: AtomicBool,
}

fn run_workers(w: &'static W) {
    let h = thread::spawn(move || while !w.stop.load(Ordering::Relaxed) {});
    w.stop.store(true, Ordering::Relaxed);
    let _ = h.join();
}

fn publish_once(w: &W) {
    let _ = w.ready.compare_exchange_weak(false, true, Ordering::AcqRel, Ordering::Acquire);
}
