// determinism fixture: the same patterns, suppressed with reasons.
use std::time::Instant;

fn timed_only() -> f64 {
    // analyze: allow(determinism) wall-clock metric only; never emitted
    let t = Instant::now();
    t.elapsed().as_secs_f64()
}

fn trailing() -> f64 {
    let t = Instant::now(); // analyze: allow(determinism) timer for a local bench
    t.elapsed().as_secs_f64()
}
