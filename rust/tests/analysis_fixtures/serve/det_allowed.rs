// determinism fixture: the same patterns, suppressed with reasons.
// serve/ is in both clock lints' scope, so each allow names both.
use std::time::Instant;

fn timed_only() -> f64 {
    // analyze: allow(determinism, obs-discipline) wall-clock metric only; never emitted
    let t = Instant::now();
    t.elapsed().as_secs_f64()
}

fn trailing() -> f64 {
    let t = Instant::now(); // analyze: allow(determinism, obs-discipline) timer for a local bench
    t.elapsed().as_secs_f64()
}
