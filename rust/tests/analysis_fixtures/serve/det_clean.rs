// determinism fixture: ordered maps and logical clocks produce nothing.
use std::collections::BTreeMap;

struct Cache {
    entries: BTreeMap<String, u64>,
}

fn iterate(c: &Cache) -> u64 {
    c.entries.values().sum()
}

fn logical_clock(t: &mut f64, dt: f64) -> f64 {
    *t += dt;
    *t
}
