// determinism fixture: every pattern the lint must catch.
use std::collections::{HashMap, HashSet};
use std::time::{Instant, SystemTime};

struct Cache {
    entries: HashMap<String, u64>,
}

fn iterate(c: &Cache) -> u64 {
    let mut sum = 0;
    for (_k, v) in &c.entries {
        sum += v;
    }
    sum
}

fn methods(c: &mut Cache) -> usize {
    let n = c.entries.keys().count();
    c.entries.retain(|_, v| *v > 0);
    n
}

fn let_bound() -> usize {
    let mut seen = HashSet::new();
    seen.insert(1u32);
    seen.iter().count()
}

fn clocks() -> f64 {
    let t = Instant::now();
    let _ = SystemTime::now();
    t.elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        let t = std::time::Instant::now();
        assert!(t.elapsed().as_secs() < 1);
    }
}
