// resource-leak fixture: a deliberately detached watcher, suppressed
// with the reason it outlives the session by design.
use std::thread;

fn detach_watcher() {
    // analyze: allow(resource-leak) daemon by design; process exit reaps it
    thread::spawn(|| {});
}
