// resource-leak fixture: joined, stored and scoped handles are all
// accounted for — nothing to report.
use std::thread;

fn join_handle() {
    let h = thread::spawn(|| {});
    let _ = h.join();
}

fn store_handles(out: &mut Vec<std::thread::JoinHandle<()>>) {
    out.push(thread::spawn(|| {}));
}

fn scoped_spawn(s: &Scope) {
    s.spawn(|| {});
}
