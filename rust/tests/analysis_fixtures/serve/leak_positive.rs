// resource-leak fixture: a discarded thread handle (detached thread),
// a named handle no path joins, and a Background handle dropped at
// the spawn statement (Drop joins immediately — the work serializes).
use std::thread;

fn detach_thread() {
    thread::spawn(|| {});
}

fn drop_named_handle() {
    let h = thread::spawn(|| {});
}

fn serialize_background() {
    Background::spawn(|| {});
}
