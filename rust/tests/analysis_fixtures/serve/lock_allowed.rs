// lock-discipline fixture: a reasoned allow on a lock unwrap.
use std::sync::Mutex;

fn stats(m: &Mutex<Vec<u64>>) -> usize {
    // analyze: allow(lock-discipline) single-threaded init; no poison possible
    m.lock().unwrap().len()
}
