// lock-discipline fixture: the poison-tolerant helper idiom is clean.
use std::sync::{Mutex, MutexGuard};

fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

fn bump(m: &Mutex<u64>) {
    *lock_or_recover(m) += 1;
}
