// lock-discipline fixture: unwrap styles and undeclared nested holds.
use std::sync::{Mutex, RwLock};

struct S {
    counters: Mutex<Vec<u64>>,
    config: RwLock<u32>,
}

fn unwrap_style(s: &S) {
    s.counters.lock().unwrap().push(1);
}

fn expect_style(s: &S) -> u32 {
    *s.config.read().expect("poisoned")
}

fn nested_held(s: &S) -> u64 {
    let c = s.counters.lock().unwrap();
    let g = s.config.read().unwrap();
    c.len() as u64 + u64::from(*g)
}
