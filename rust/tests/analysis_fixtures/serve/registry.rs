// lock-discipline fixture: this file's path ends in serve/registry.rs,
// so the declared order ["inner", "tenants", "current"] applies — and
// `inner` is acquired below while `tenants` is held.
use std::sync::{Mutex, MutexGuard, RwLock, RwLockWriteGuard};

fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

fn write_or_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    match l.write() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

struct R {
    inner: Mutex<u64>,
    tenants: RwLock<Vec<String>>,
}

fn inverted(r: &R) -> u64 {
    let t = write_or_recover(&r.tenants);
    let i = lock_or_recover(&r.inner);
    *i + t.len() as u64
}
