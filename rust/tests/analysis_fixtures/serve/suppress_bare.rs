// suppression fixture: a bare allow is itself a finding and suppresses
// nothing.
// analyze: allow(panic-path)
fn nothing() {}
