// suppression fixture: an unrecognized directive shape is a finding.
// analyze: forbid(panic-path) not a directive the pass knows
fn nothing() {}
