// suppression fixture: a typo'd lint name is a finding.
// analyze: allow(panics) typo'd lint name
fn nothing() {}
