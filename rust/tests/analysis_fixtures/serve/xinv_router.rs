// lock-order-transitive fixture (cross-file pair, caller half): holds
// `tenants` and calls xinv_table.rs's `refresh_routes`, which acquires
// `inner` — `inner` precedes `tenants` in GLOBAL_ORDER, and the
// inversion is attributed here, at the call site that reaches it.
use std::sync::RwLock;

pub struct Router {
    pub tenants: RwLock<u64>,
}

pub fn reroute(r: &Router, t: &RouteTable) {
    let g = write_or_recover(&r.tenants);
    refresh_routes(t);
    drop(g);
}
