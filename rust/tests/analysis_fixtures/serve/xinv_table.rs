// lock-order-transitive fixture (cross-file pair, callee half): the
// routing refresh acquires `inner`; xinv_router.rs reaches it while
// holding `tenants`.
use std::sync::Mutex;

pub struct RouteTable {
    pub inner: Mutex<u64>,
}

pub fn refresh_routes(t: &RouteTable) {
    *lock_or_recover(&t.inner) += 1;
}
