// lock-order-transitive fixture: the same cross-call inversion shape,
// suppressed with the invariant that makes it sound.
use std::sync::Mutex;

struct A {
    registry: Mutex<u64>,
    store: Mutex<u64>,
}

fn reindex_allowed(a: &A) {
    *lock_or_recover(&a.registry) += 1;
}

fn swap_allowed(a: &A) {
    let g = lock_or_recover(&a.store);
    // analyze: allow(lock-order-transitive) single-threaded recovery; no other holder exists yet
    reindex_allowed(a);
    drop(g);
}
