// lock-order-transitive fixture: a cross-call acquisition that
// follows GLOBAL_ORDER (`inner` before `tenants`) produces nothing.
use std::sync::Mutex;

struct C {
    inner: Mutex<u64>,
    tenants: Mutex<u64>,
}

fn tag_clean(c: &C) {
    *lock_or_recover(&c.tenants) += 1;
}

fn order_clean(c: &C) {
    let g = lock_or_recover(&c.inner);
    tag_clean(c);
    drop(g);
}
