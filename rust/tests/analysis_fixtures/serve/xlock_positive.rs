// lock-order-transitive fixture: the held-guard set propagates
// through calls — `reindex` acquires `registry` while `store` (which
// follows it in GLOBAL_ORDER) is held, and `reprice` re-acquires the
// `cfg` its caller already holds.
use std::sync::Mutex;

struct S {
    registry: Mutex<u64>,
    store: Mutex<u64>,
    cfg: Mutex<u64>,
}

fn reindex(s: &S) {
    *lock_or_recover(&s.registry) += 1;
}

fn reprice(s: &S) {
    *lock_or_recover(&s.cfg) += 1;
}

fn swap_under_store(s: &S) {
    let g = lock_or_recover(&s.store);
    reindex(s);
    drop(g);
}

fn bump_under_cfg(s: &S) {
    let g = lock_or_recover(&s.cfg);
    reprice(s);
    drop(g);
}
