// blocking-under-lock fixture: the same fsync under the guard,
// suppressed with the atomicity argument that makes it deliberate.
use std::fs::File;
use std::sync::Mutex;

struct E {
    wal: Mutex<u64>,
}

fn sync_under_wal(e: &E, f: &mut File) -> std::io::Result<()> {
    let g = lock_or_recover(&e.wal);
    // analyze: allow(blocking-under-lock) the fsync must be atomic with the guarded bump
    f.sync_data()?;
    drop(g);
    Ok(())
}
