// blocking-under-lock fixture: the guard is scoped to the in-RAM
// mutation and the fsync runs after it drops — nothing to report.
use std::fs::File;
use std::sync::Mutex;

struct F {
    wal: Mutex<u64>,
}

fn append_then_sync(x: &F, f: &mut File) -> std::io::Result<()> {
    {
        let g = lock_or_recover(&x.wal);
        *g += 1;
    }
    f.sync_data()
}
