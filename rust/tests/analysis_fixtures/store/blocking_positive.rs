// blocking-under-lock fixture: fsync directly under the held WAL
// guard, and a bulk write reached through a call while it is held.
use std::fs::File;
use std::io::Write;
use std::sync::Mutex;

struct D {
    wal: Mutex<u64>,
}

fn flush_segment(f: &mut File, buf: &[u8]) -> std::io::Result<()> {
    f.write_all(buf)
}

fn append_under_wal(d: &D, f: &mut File, buf: &[u8]) -> std::io::Result<()> {
    let g = lock_or_recover(&d.wal);
    f.sync_data()?;
    flush_segment(f, buf)?;
    drop(g);
    Ok(())
}
