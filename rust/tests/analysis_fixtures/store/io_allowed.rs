// io-durability fixture: a reasoned allow on an advisory cache file.
fn cache_hint(path: &std::path::Path, bytes: &[u8]) -> std::io::Result<()> {
    // analyze: allow(io-durability) advisory cache file; loss is harmless
    std::fs::write(path, bytes)
}
