// io-durability fixture: the write + fsync idiom produces nothing.
use std::fs::File;
use std::io::Write;

fn persist(path: &std::path::Path, bytes: &[u8]) -> std::io::Result<()> {
    let mut f = File::create(path)?;
    f.write_all(bytes)?;
    f.sync_all()
}
