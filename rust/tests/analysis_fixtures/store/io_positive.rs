// io-durability fixture: store/ writes with no fsync in the same fn.
use std::fs::File;
use std::io::Write;

fn persist(path: &std::path::Path, bytes: &[u8]) -> std::io::Result<()> {
    let mut f = File::create(path)?;
    f.write_all(bytes)
}

fn dump(path: &std::path::Path, bytes: &[u8]) -> std::io::Result<()> {
    std::fs::write(path, bytes)
}
