// panic-path fixture: a reasoned allow on a checked index.
fn first(v: &[u8]) -> u8 {
    // analyze: allow(panic-path) caller bounds-checks via the framing header
    v[0]
}
