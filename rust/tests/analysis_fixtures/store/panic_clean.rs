// panic-path fixture: typed-error idioms produce nothing.
fn first(v: &[u8]) -> Option<u8> {
    v.first().copied()
}

fn parse(s: &str) -> Result<u64, std::num::ParseIntError> {
    s.parse()
}
