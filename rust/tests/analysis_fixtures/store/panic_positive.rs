// panic-path fixture: every panicking shape the lint must catch.
fn first(v: &[u8]) -> u8 {
    v[0]
}

fn parse(s: &str) -> u64 {
    s.parse().unwrap()
}

fn must(o: Option<u64>) -> u64 {
    o.expect("present")
}

fn boom(flag: bool) {
    if flag {
        panic!("no");
    }
    unreachable!()
}
