// framing-casts fixture: checked conversions produce nothing.
fn narrow(len: usize) -> Result<u32, std::num::TryFromIntError> {
    u32::try_from(len)
}

fn widen(x: u16) -> usize {
    usize::from(x)
}
