// framing-casts fixture: a reasoned allow on a masked (lossless) cast.
fn table_index(masked: u32) -> usize {
    // analyze: allow(framing-casts) masked to 8 bits on this line; lossless
    (masked & 0xff) as usize
}
