// framing-casts fixture: this path ends in store/wal.rs, so bare
// narrowing casts are findings.
fn encode(len: usize) -> [u8; 2] {
    (len as u16).to_le_bytes()
}

fn widen(x: u16, y: u32) -> usize {
    x as usize + y as usize
}

fn frame_len(payload: &[u8]) -> u32 {
    payload.len() as u32
}
