//! Randomized round-trip property tests for the QPCK checkpoint
//! container (ISSUE-5 satellite): seeded shapes, dtypes and tenant
//! names through `save_adapter` / `load_adapter`, pinning the v3
//! whole-payload checksum together with the hostile-header caps — every
//! random checkpoint round-trips bit-exactly, and every single-byte
//! corruption of it is rejected at load.

use quantum_peft::coordinator::checkpoint::{
    load, load_adapter, save_adapter, save_adapter_atomic, AdapterManifest,
};
use quantum_peft::runtime::HostTensor;
use quantum_peft::util::rng::Rng;

fn tdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("qp_ckpt_prop")
        .join(format!("{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A random but valid tenant name (1..=24 alphanumeric-ish chars).
fn random_tenant(rng: &mut Rng) -> String {
    const ALPHABET: &[u8] =
        b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789-_";
    let len = rng.range(1, 25);
    (0..len)
        .map(|_| ALPHABET[rng.below(ALPHABET.len())] as char)
        .collect()
}

/// A random tensor: 0..=3 dims of 1..=6 each, f32 or i32 payload.
fn random_tensor(rng: &mut Rng, index: usize) -> (String, HostTensor) {
    let name = format!("tensor_{index}_{}", random_tenant(rng));
    let ndim = rng.below(4);
    let shape: Vec<usize> = (0..ndim).map(|_| rng.range(1, 7)).collect();
    let numel: usize = shape.iter().product();
    let tensor = if rng.chance(0.5) {
        HostTensor::f32(
            shape,
            (0..numel).map(|_| rng.normal() as f32 * 3.0).collect(),
        )
    } else {
        HostTensor::i32(
            shape,
            (0..numel).map(|_| rng.below(1 << 20) as i32 - (1 << 19)).collect(),
        )
    };
    (name, tensor)
}

#[test]
fn random_adapters_roundtrip_bit_exactly() {
    let dir = tdir("roundtrip");
    let mut rng = Rng::new(0xc4ec_4b07);
    for iter in 0..32 {
        let manifest = AdapterManifest {
            tenant: random_tenant(&mut rng),
            q: rng.range(1, 13) as u32,
            n_layers: rng.below(4) as u32,
        };
        let n_tensors = rng.range(1, 5);
        let tensors: Vec<(String, HostTensor)> =
            (0..n_tensors).map(|i| random_tensor(&mut rng, i)).collect();
        let path = dir.join(format!("rt{iter}.qpck"));
        if rng.chance(0.5) {
            save_adapter(&path, &manifest, &tensors).unwrap();
        } else {
            save_adapter_atomic(&path, &manifest, &tensors).unwrap();
        }
        let (back_m, back_t) = load_adapter(&path).unwrap();
        assert_eq!(back_m, manifest, "iter={iter}");
        assert_eq!(back_t, tensors, "iter={iter}");
        // the plain (manifest-skipping) loader sees the same tensors
        assert_eq!(load(&path).unwrap(), tensors, "iter={iter}");
    }
}

#[test]
fn every_single_byte_corruption_of_a_random_adapter_is_rejected() {
    let dir = tdir("corrupt");
    let mut rng = Rng::new(0xbad_c0de);
    for iter in 0..8 {
        let manifest = AdapterManifest {
            tenant: random_tenant(&mut rng),
            q: rng.range(1, 13) as u32,
            n_layers: rng.below(3) as u32,
        };
        let tensors = vec![random_tensor(&mut rng, 0)];
        let path = dir.join(format!("c{iter}.qpck"));
        save_adapter(&path, &manifest, &tensors).unwrap();
        let clean = std::fs::read(&path).unwrap();
        // a handful of random positions plus the structural hot spots
        let mut positions: Vec<usize> =
            (0..24).map(|_| rng.below(clean.len())).collect();
        positions.extend([0, 4, 8, clean.len() - 9, clean.len() - 1]);
        let victim = dir.join(format!("c{iter}_bad.qpck"));
        for pos in positions {
            let mut bad = clean.clone();
            bad[pos] ^= 1u8 << rng.below(8);
            std::fs::write(&victim, &bad).unwrap();
            assert!(
                load_adapter(&victim).is_err(),
                "iter={iter}: byte flip at {pos} loaded successfully"
            );
        }
        // truncation at any depth is also always rejected
        for frac in [1, 2, 3, 5] {
            let cut = clean.len() * frac / 6;
            std::fs::write(&victim, &clean[..cut]).unwrap();
            assert!(load_adapter(&victim).is_err(), "iter={iter} cut={cut}");
        }
    }
}

#[test]
fn hostile_caps_and_checksum_hold_together() {
    // the caps pin down hostile *headers*; the checksum pins hostile
    // *payloads*. Both must hold on the same file format version.
    let dir = tdir("hostile");
    let m = AdapterManifest { tenant: "acme".into(), q: 4, n_layers: 1 };
    let path = dir.join("base.qpck");
    save_adapter(&path, &m, &[(
        "thetas".to_string(),
        HostTensor::f32(vec![12], vec![0.25; 12]),
    )]).unwrap();
    let clean = std::fs::read(&path).unwrap();
    // version is 3 and the trailer is present
    assert_eq!(&clean[4..8], &3u32.to_le_bytes());

    // hostile header on the *current* version: tenant_len beyond the cap
    // must fail on the cap check, before any checksum work
    let p = dir.join("tenant_cap.qpck");
    let mut b = clean.clone();
    b[8..12].copy_from_slice(&(1u32 << 20).to_le_bytes());
    std::fs::write(&p, &b).unwrap();
    let e = load_adapter(&p).unwrap_err().to_string();
    assert!(e.contains("tenant_len") && e.contains("exceeds cap"), "{e}");

    // oversized tenant id refused at save time too
    let long = AdapterManifest {
        tenant: "x".repeat(300),
        q: 4,
        n_layers: 1,
    };
    let e = save_adapter(&dir.join("never.qpck"), &long, &[])
        .unwrap_err()
        .to_string();
    assert!(e.contains("exceeds cap"), "{e}");

    // a payload flip on the same base file is caught by the checksum
    // with its dedicated message
    let p = dir.join("payload.qpck");
    let mut b = clean.clone();
    let pos = clean.len() - 16; // inside the theta payload
    b[pos] ^= 0x10;
    std::fs::write(&p, &b).unwrap();
    let e = load_adapter(&p).unwrap_err().to_string();
    assert!(e.contains("payload checksum mismatch"), "{e}");
}
