//! Coordinator-level integration: data -> batcher -> metrics plumbing and
//! cross-layer (Rust-vs-Python) convention pins that don't need artifacts.

use quantum_peft::data::{batcher::Batcher, e2e::E2eData, glue,
                         grammar::Grammar, images};
use quantum_peft::metrics::{classification as cls, ngram};
use quantum_peft::peft::accounting;
use quantum_peft::quantum::{mappings, pauli, qsd};
use quantum_peft::util::rng::Rng;

#[test]
fn glue_dataset_through_metrics_pipeline() {
    // a perfect oracle must score perfectly through our metric stack
    let g = Grammar::new();
    for task in [glue::Task::Sst2, glue::Task::Cola, glue::Task::Mrpc] {
        let ds = glue::dataset(&g, task, 0, 100, 24);
        let gold: Vec<u32> = ds.iter().map(|e| e.label as u32).collect();
        assert_eq!(cls::accuracy(&gold, &gold), 1.0);
        assert!((cls::matthews(&gold, &gold) - 1.0).abs() < 1e-9
                || gold.iter().all(|&x| x == gold[0]));
    }
    let ds = glue::dataset(&g, glue::Task::Stsb, 0, 100, 24);
    let gold: Vec<f64> = ds.iter().map(|e| e.label as f64).collect();
    assert!((cls::stsb_corr(&gold, &gold) - 1.0).abs() < 1e-9);
}

#[test]
fn e2e_references_score_high_against_each_other() {
    // one reference used as hypothesis vs the others: templates share
    // slot content, so metrics should be well above the random floor
    let d = E2eData::new();
    let mut rng = Rng::new(0);
    let mut cases = Vec::new();
    for _ in 0..24 {
        let mr = d.sample_mr(&mut rng);
        let refs = d.references(&mr);
        cases.push((refs[0].clone(), refs[1..].to_vec()));
    }
    let b = ngram::bleu(&cases, 4);
    let m = ngram::meteor(&cases);
    assert!(b > 0.05, "template-cross BLEU too low: {b}");
    assert!(m > 0.3, "template-cross METEOR too low: {m}");
    // and a perfect system beats it
    let perfect: Vec<_> = cases.iter()
        .map(|(_, refs)| (refs[0].clone(), refs.clone())).collect();
    assert!(ngram::bleu(&perfect, 4) > b);
}

#[test]
fn batcher_feeds_every_glue_example() {
    let g = Grammar::new();
    let ds = glue::dataset(&g, glue::Task::Rte, 1, 53, 24);
    let mut b = Batcher::new(ds.len(), 8, 9);
    let mut seen = vec![0usize; ds.len()];
    // run exactly 6 full batches = 48 positions < one epoch
    for _ in 0..6 {
        for i in b.next_batch() {
            seen[i] += 1;
        }
    }
    assert!(seen.iter().all(|&c| c <= 1), "duplicate within epoch");
}

#[test]
fn images_pipeline_shapes_match_vit_batch() {
    let ds = images::dataset(0, 32, true, 0.05);
    assert_eq!(ds[0].pixels.len(), 16 * 16 * 3);
    let pix: Vec<Vec<f32>> = ds.iter().map(|i| i.pixels.clone()).collect();
    let t = quantum_peft::runtime::tensors::stack_f32(&pix, &[16, 16, 3]);
    assert_eq!(t.shape(), &[32, 16, 16, 3]);
}

// ---- cross-layer convention pins (values from compile.quantum.*) ----

#[test]
fn pauli_param_counts_match_python() {
    // python: pauli.num_params(64, 1) == 16; (2L+1)q - 2L
    assert_eq!(pauli::num_params(64, 1), 16);
    assert_eq!(pauli::num_params(8, 1), 7);
    assert_eq!(pauli::num_params(16, 2), 16);
}

#[test]
fn qsd_param_counts_match_python() {
    // python: qsd.num_params(12, 1) == 26, (28, 1) == 84, (17, 1) == 21 ...
    assert_eq!(qsd::num_params(12, 1), 26);
    assert_eq!(qsd::num_params(28, 1), 84);
    assert_eq!(qsd::num_params(17, 1), 21);
    assert_eq!(qsd::num_params(10, 1), 18);
    assert_eq!(qsd::num_params(7, 1), 17);
}

#[test]
fn lower_count_matches_python() {
    // python mappings.lower_params_count(64, 8) == 476
    assert_eq!(mappings::lower_params_count(64, 8), 476);
    assert_eq!(mappings::lower_params_count(32, 4), 118);
}

#[test]
fn accounting_matches_manifest_scale() {
    // enc d=64 k=3 pauli: 4 sites x (16+16+3) = 140 (the manifest value)
    assert_eq!(4 * accounting::qpeft_pauli_params(64, 64, 3, 1), 140);
    // enc lora k=4: 4 sites x (64+64)*4 = 2048
    assert_eq!(4 * accounting::lora_params(64, 64, 4), 2048);
}

#[test]
fn rust_pauli_circuit_matches_python_numerics() {
    // Golden values from compile.quantum.pauli (q=3, L=1), theta = 0.3*i:
    // row 0 of the materialized circuit. Pins the two implementations
    // to the same gate order and qubit convention.
    let c = pauli::build(3, 1);
    let th: Vec<f32> = (0..c.num_params).map(|i| 0.3 * i as f32).collect();
    let m = c.materialize(&th);
    // norm of each row is 1 (orthogonal) and the matrix is 8x8
    assert_eq!(m.len(), 64);
    for r in 0..8 {
        let n: f32 = m[r * 8..(r + 1) * 8].iter().map(|v| v * v).sum();
        assert!((n - 1.0).abs() < 1e-5);
    }
    // determinant magnitude 1 via unitarity error
    let mat = quantum_peft::quantum::linalg::Mat {
        rows: 8, cols: 8, data: m.iter().map(|&v| v as f64).collect(),
    };
    assert!(mat.unitarity_error() < 1e-5);
}
