//! Parallel E2E (Table 3/4) panel + shared compile cache guards, through
//! the public `run_panel_with` / `table3_and_4_rows` / `exe_cache` APIs
//! with a synthetic cell runner — no artifacts required:
//!
//! - jobs=1 vs jobs=N must produce byte-identical results and rendered
//!   tables (the Table 3/4 determinism contract);
//! - under a concurrent panel, the shared cache must compile each
//!   distinct artifact path exactly once, asserted on the aggregated
//!   compile log.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use quantum_peft::coordinator::events::EventLog;
use quantum_peft::coordinator::sweep;
use quantum_peft::coordinator::trainer::RunResult;
use quantum_peft::report::{self, tables};
use quantum_peft::runtime::exe_cache::{CacheEvent, CompileLog, OnceMap};
use quantum_peft::runtime::{Runtime, WorkerRuntime};
use quantum_peft::util::rng::Rng;

const TAGS: [&str; 6] = ["dec_ft", "dec_lora", "dec_adalora",
                         "dec_loha", "dec_lokr", "dec_qpeft_taylor"];

/// Deterministic stand-in for `trainer::run_e2e`: every metric is a pure
/// function of the tag (like a real run with isolated RNG streams); the
/// sleep scrambles completion order across workers.
fn fake_e2e(tag: &str, sleep: bool) -> RunResult {
    let h: u64 = tag.bytes().map(|b| b as u64).sum();
    let mut rng = Rng::new(h);
    let mut extra = BTreeMap::new();
    for k in ["bleu", "nist", "meteor", "rouge_l", "cider"] {
        extra.insert(k.to_string(), rng.f64());
    }
    if sleep {
        std::thread::sleep(Duration::from_millis(rng.below(8) as u64));
    }
    let bleu = extra["bleu"];
    RunResult {
        tag: tag.to_string(),
        task: "e2e".into(),
        metric_name: "bleu".into(),
        best_metric: bleu,
        final_metric: bleu,
        losses: vec![],
        adapter_params: 10 + h as usize,
        trainable_params: 20 + h as usize,
        wall_seconds: 0.0,
        step_ms: h as f64,
        extra_metrics: extra,
    }
}

fn run_panel(jobs: usize) -> Vec<RunResult> {
    let items: Vec<String> = TAGS.iter().map(|s| s.to_string()).collect();
    sweep::run_panel_with(items, jobs, &EventLog::null(), |_w| Ok(()),
                          |_s, tag, _wlog| Ok(fake_e2e(tag, jobs > 1)))
        .unwrap()
}

/// The full rendered Table 3 + Table 4 text, for byte comparison.
fn render(results: &[RunResult]) -> String {
    let (t3, t4) = tables::table3_and_4_rows(results);
    format!("{}{}", report::render_table(&t3.0, &t3.1),
            report::render_table(&t4.0, &t4.1))
}

#[test]
fn e2e_panel_jobs_1_vs_jobs_n_renders_byte_identical_tables() {
    let seq = run_panel(1);
    assert_eq!(seq.len(), TAGS.len());
    let seq_text = render(&seq);
    for jobs in [2, 4, 8] {
        let par = run_panel(jobs);
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.tag, b.tag);
            assert_eq!(a.best_metric.to_bits(), b.best_metric.to_bits());
            for (k, v) in &a.extra_metrics {
                assert_eq!(v.to_bits(), b.extra_metrics[k].to_bits(),
                           "{}/{k} diverged at jobs={jobs}", a.tag);
            }
        }
        assert_eq!(seq_text, render(&par),
                   "rendered tables diverged at jobs={jobs}");
    }
}

#[test]
fn e2e_panel_results_follow_input_order_not_completion_order() {
    let par = run_panel(4);
    for (tag, r) in TAGS.iter().zip(&par) {
        assert_eq!(*tag, r.tag);
    }
}

#[test]
fn e2e_panel_failure_surfaces_root_cause() {
    let items: Vec<String> = TAGS.iter().map(|s| s.to_string()).collect();
    for jobs in [1, 4] {
        let err = sweep::run_panel_with(
            items.clone(), jobs, &EventLog::null(), |_w| Ok(()),
            |_s, tag: &String, _wlog| {
                if tag == "dec_lokr" {
                    anyhow::bail!("lokr cell refused");
                }
                Ok(fake_e2e(tag, false))
            })
            .unwrap_err();
        assert!(err.to_string().contains("lokr cell refused"), "{err}");
    }
}

#[test]
fn table4_memory_column_normalizes_to_the_qpeft_row() {
    let results = run_panel(1);
    let (_, t4) = tables::table3_and_4_rows(&results);
    let qpeft_ix = TAGS.iter().position(|t| t.contains("qpeft")).unwrap();
    assert_eq!(t4.1[qpeft_ix][2], "1.00x");
}

#[test]
fn share_client_env_override_forces_the_private_worker_fallback() {
    // No other test reads REPRO_SHARE_CLIENT, so the set/remove window
    // cannot race a parallel test in this binary.
    std::env::set_var("REPRO_SHARE_CLIENT", "0");
    let rt = Runtime::cpu().unwrap();
    assert!(!rt.supports_concurrent_execution());
    let w = rt.for_worker(3).unwrap();
    match &w {
        WorkerRuntime::Private(p) => {
            // private worker runtimes stay on the caller's shared cache
            assert!(std::sync::Arc::ptr_eq(p.cache(), rt.cache()));
        }
        WorkerRuntime::Shared(_) => panic!("expected the private fallback"),
    }
    drop(w); // evicts the worker client's (empty) executable namespace
    std::env::remove_var("REPRO_SHARE_CLIENT");
    assert!(rt.supports_concurrent_execution());
    assert!(matches!(rt.for_worker(0).unwrap(), WorkerRuntime::Shared(_)));
}

#[test]
fn shared_cache_compiles_each_path_exactly_once_under_parallel_panel() {
    // Every cell loads three panel-wide artifacts plus one per-tag
    // adapter artifact through one shared cache while 8 workers run
    // concurrently: 3 + |TAGS| distinct paths, each compiled exactly
    // once — the others block on the in-flight compile and share it.
    let cache: OnceMap<PathBuf, usize> = OnceMap::new();
    let log = CompileLog::new();
    let compiles = AtomicUsize::new(0);
    let items: Vec<String> = TAGS.iter().map(|s| s.to_string()).collect();
    let results = sweep::run_panel_with(
        items, 8, &EventLog::null(), |w| Ok(w),
        |w, tag, _wlog| {
            let tag_art = format!("artifacts/{tag}.hlo");
            let paths = ["artifacts/shared_init.hlo",
                         "artifacts/shared_train.hlo",
                         "artifacts/shared_eval.hlo",
                         tag_art.as_str()];
            for p in paths {
                let path = PathBuf::from(p);
                cache.get_or_try_init(&path, || {
                    compiles.fetch_add(1, Ordering::SeqCst);
                    // widen the in-flight window so workers pile up
                    std::thread::sleep(Duration::from_millis(3));
                    log.record(&path, CacheEvent::Compile, 0.003, Some(*w));
                    Ok(1usize)
                })?;
            }
            Ok(fake_e2e(tag, true))
        })
        .unwrap();
    assert_eq!(results.len(), TAGS.len());
    let distinct = 3 + TAGS.len();
    assert_eq!(compiles.load(Ordering::SeqCst), distinct,
               "a concurrent worker re-compiled a cached path");
    let per_path = log.compiles_per_path();
    assert_eq!(per_path.len(), distinct);
    for (path, n) in per_path {
        assert_eq!(n, 1, "{path:?} compiled {n} times, expected exactly 1");
    }
    assert_eq!(cache.len(), distinct);
}
