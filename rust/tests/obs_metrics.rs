//! The process-wide metrics backplane (PR 10): the fifo determinism
//! oracle — exported snapshots (Prometheus text *and* JSONL) must be
//! byte-identical at any worker count, for both the sweep engine and
//! the sharded serving tier — plus the timed-mode smoke test (lock,
//! pool, exe-cache and WAL metrics all move) and the `Hist` merge /
//! quantile properties the exporters rely on.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use quantum_peft::coordinator::events::EventLog;
use quantum_peft::coordinator::sweep::{self, Cell, SweepObs, SweepPlan};
use quantum_peft::coordinator::trainer::{RunResult, TrainConfig};
use quantum_peft::data::glue;
use quantum_peft::obs::export::{render_jsonl, render_prometheus};
use quantum_peft::obs::{Hist, MetricsRegistry, Reading};
use quantum_peft::runtime::exe_cache::{CacheObs, OnceMap};
use quantum_peft::serve::loadgen::{self, BenchOpts, LoadSpec};
use quantum_peft::serve::registry::theta_checksum;
use quantum_peft::serve::scheduler::BatchPolicy;
use quantum_peft::serve::{percentile_us, PauliSpec, ServeConfig};
use quantum_peft::store::{Durability, StateRecord, StateStore, TenantState};
use quantum_peft::util::pool;
use quantum_peft::util::rng::Rng;

// ------------------------------------------------------- fifo byte-identity

/// Render both export formats from one deterministic registry.
fn renders(reg: &MetricsRegistry) -> (String, String) {
    let snap = reg.snapshot();
    (render_prometheus(&snap), render_jsonl(&snap))
}

fn sweep_plan() -> SweepPlan {
    SweepPlan {
        tags: vec!["enc_qpeft_pauli".to_string(), "enc_lora".to_string()],
        tasks: vec![glue::Task::Sst2, glue::Task::Cola],
        seeds: vec![0, 1, 2],
        cfg: TrainConfig::default(),
        backbone: None,
        task_lr: BTreeMap::new(),
    }
}

/// Pure stand-in for a training cell (same shape as
/// `tests/sweep_parallel.rs`); the sleep scrambles completion order so
/// parallel runs genuinely race.
fn fake_cell(cell: &Cell, cfg: &TrainConfig, sleep: bool) -> RunResult {
    let tag_hash: u64 = cell.tag.bytes().map(|b| b as u64).sum();
    let task_hash: u64 = cell.task.name().bytes().map(|b| b as u64).sum();
    let mut rng = Rng::new(cfg.seed ^ (tag_hash << 16) ^ (task_hash << 32));
    let metric = rng.f64();
    if sleep {
        std::thread::sleep(Duration::from_millis(rng.below(6) as u64));
    }
    RunResult {
        tag: cell.tag.clone(),
        task: cell.task.name().to_string(),
        metric_name: cell.task.metric_name().to_string(),
        best_metric: metric,
        final_metric: metric,
        losses: vec![],
        adapter_params: 100,
        trainable_params: 200,
        wall_seconds: 0.0,
        step_ms: 1.0,
        extra_metrics: BTreeMap::new(),
    }
}

#[test]
fn sweep_metrics_snapshot_is_byte_identical_across_jobs() {
    let mk = |jobs: usize| {
        let reg = MetricsRegistry::new(true);
        let obs = SweepObs::register(&reg, jobs);
        let results = sweep::run_plan_with_obs(
            &sweep_plan(),
            jobs,
            &EventLog::null(),
            |_w| Ok(()),
            |_s, cell, cfg, _wlog| Ok(fake_cell(cell, &cfg, jobs > 1)),
            &obs,
        )
        .unwrap();
        assert_eq!(results.len(), 12, "jobs={jobs}");
        assert_eq!(obs.cells(), 12, "jobs={jobs}");
        renders(&reg)
    };
    let (prom1, json1) = mk(1);
    // the deterministic snapshot keeps the Stable cell counter and
    // drops the scheduling-dependent pool_* metrics entirely
    assert!(prom1.contains("sweep_cells_total 12"), "{prom1}");
    assert!(!prom1.contains("pool_"), "{prom1}");
    assert!(json1.contains("sweep_cells_total"), "{json1}");
    for jobs in [4, 8] {
        let (prom, json) = mk(jobs);
        assert_eq!(prom, prom1, "prometheus text diverged at jobs={jobs}");
        assert_eq!(json, json1, "jsonl diverged at jobs={jobs}");
    }
}

fn bench_opts(workers: usize, tenants: usize) -> BenchOpts {
    BenchOpts {
        load: LoadSpec {
            tenants,
            requests: 192,
            concurrency: 24,
            seed: 7,
            zipf_s: 1.1,
            pauli: PauliSpec { q: 4, n_layers: 1 },
            open_rate_rps: 0.0,
        },
        serve: ServeConfig {
            workers,
            policy: BatchPolicy { max_batch: 5, max_wait_us: 1 },
            fifo: true,
            metrics: Some(MetricsRegistry::new(true)),
            ..ServeConfig::default()
        },
        cache_bytes: 1 << 20,
        ..BenchOpts::default()
    }
}

#[test]
fn serve_bench_fifo_snapshot_is_byte_identical_across_workers() {
    let mk = |workers: usize| {
        let opts = bench_opts(workers, 8);
        let (summary, _log) =
            loadgen::run_serve_bench(&opts, &EventLog::null()).unwrap();
        assert_eq!(summary.completed, 192, "workers={workers}");
        renders(opts.serve.metrics.as_ref().unwrap())
    };
    let (prom1, json1) = mk(1);
    assert!(prom1.contains("serve_requests_completed_total 192"), "{prom1}");
    assert!(prom1.contains("serve_latency_ns_count 192"), "{prom1}");
    // lock_*/pool_* are Volatile: absent from the deterministic export
    assert!(!prom1.contains("lock_"), "{prom1}");
    for workers in [4, 8] {
        let (prom, json) = mk(workers);
        assert_eq!(prom, prom1, "prometheus text diverged at workers={workers}");
        assert_eq!(json, json1, "jsonl diverged at workers={workers}");
    }
}

#[test]
fn sharded_bench_fifo_snapshot_is_byte_identical_across_workers() {
    let mk = |workers: usize| {
        let opts = bench_opts(workers, 16);
        let report = loadgen::run_sharded_bench(&opts, 4, &EventLog::null()).unwrap();
        assert_eq!(report.fleet.completed(), 192, "workers={workers}");
        renders(opts.serve.metrics.as_ref().unwrap())
    };
    let (prom1, json1) = mk(1);
    // the four shards share one registry Arc and sum into fleet totals
    assert!(prom1.contains("serve_requests_completed_total 192"), "{prom1}");
    for workers in [4, 8] {
        let (prom, json) = mk(workers);
        assert_eq!(prom, prom1, "prometheus text diverged at workers={workers}");
        assert_eq!(json, json1, "jsonl diverged at workers={workers}");
    }
}

// --------------------------------------------------------- timed-mode smoke

fn tdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("qp_obs_metrics")
        .join(format!("{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Sum a counter across every label set it was registered under.
fn counter_sum(reg: &MetricsRegistry, name: &str) -> u64 {
    reg.snapshot_full()
        .iter()
        .filter(|v| v.name == name)
        .map(|v| match v.reading {
            Reading::Counter(n) => n,
            _ => panic!("{name} is not a counter"),
        })
        .sum()
}

fn hist_count(reg: &MetricsRegistry, name: &str) -> u64 {
    reg.snapshot_full()
        .iter()
        .filter(|v| v.name == name)
        .map(|v| match &v.reading {
            Reading::Hist { count, .. } => *count,
            _ => panic!("{name} is not a histogram"),
        })
        .sum()
}

#[test]
fn timed_mode_smoke_every_layer_reports_nonzero() {
    let reg = MetricsRegistry::new(false);

    // store + WAL + the store's observed lock, with per-append fsync
    let dir = tdir("smoke");
    let mut opened = StateStore::open(&dir, Durability::Always).unwrap();
    opened.store.instrument(&reg, &opened.recovered);
    let spec = PauliSpec { q: 3, n_layers: 1 };
    for (i, tenant) in ["alpha", "beta"].iter().enumerate() {
        let mut rng = Rng::new(0x0b5_0000 ^ i as u64);
        let thetas: Vec<f32> =
            (0..spec.num_params()).map(|_| rng.normal() as f32 * 0.5).collect();
        opened
            .store
            .append(&StateRecord::Register(TenantState {
                tenant: tenant.to_string(),
                version: 1,
                q: spec.q,
                n_layers: spec.n_layers,
                checksum: theta_checksum(&thetas),
                path: String::new(),
                thetas,
            }))
            .unwrap();
    }
    opened.store.sync().unwrap();

    // worker pool with wall-clock busy time
    let pobs = pool::PoolObs::register(&reg, "smoke", 2);
    let out = pool::run_stateful_obs(
        2,
        (0..8u32).collect::<Vec<_>>(),
        |_w| Ok(()),
        |_s, _ctx, i| {
            std::thread::sleep(Duration::from_millis(2));
            Ok(i)
        },
        &pobs,
    );
    assert!(out.iter().all(|r| r.is_ok()));

    // compile-cache hit/miss accounting
    let map: OnceMap<u32, u32> = OnceMap::new();
    map.instrument(CacheObs::register(&reg, "smoke"));
    assert_eq!(map.get_or_try_init(&1, || Ok(10)).unwrap(), 10);
    assert_eq!(map.get_or_try_init(&1, || Ok(99)).unwrap(), 10);

    assert!(counter_sum(&reg, "wal_appends_total") >= 2);
    assert!(counter_sum(&reg, "wal_append_bytes_total") > 0);
    // Durability::Always fsyncs every append, plus the explicit sync
    assert!(counter_sum(&reg, "wal_fsyncs_total") >= 2);
    assert!(hist_count(&reg, "wal_append_ns") >= 2);
    assert!(counter_sum(&reg, "lock_acquires_total") >= 2, "store_wal lock");
    assert!(hist_count(&reg, "lock_wait_ns") >= 2);
    let busy: u64 = (0..2).map(|w| pobs.busy_ns(w)).sum();
    assert!(busy > 0, "2ms sleeps must land in pool_worker_busy_ns");
    assert!(counter_sum(&reg, "exe_cache_misses_total") >= 1);
    assert!(counter_sum(&reg, "exe_cache_hits_total") >= 1);

    let _ = std::fs::remove_dir_all(&dir);
}

// ----------------------------------------------------------- Hist properties

fn hist_of(values: &[u64]) -> Hist {
    let h = Hist::new();
    for &v in values {
        h.record(v);
    }
    h
}

fn seeded_values(seed: u64, n: usize, max: usize) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.below(max) as u64).collect()
}

#[test]
fn hist_merge_is_associative_and_commutative() {
    let a = seeded_values(11, 200, 5_000_000);
    let b = seeded_values(22, 150, 300);
    let c = seeded_values(33, 75, 40_000_000_000);

    // (a ∪ b) ∪ c
    let left = hist_of(&a);
    left.merge_from(&hist_of(&b));
    left.merge_from(&hist_of(&c));
    // a ∪ (b ∪ c)
    let bc = hist_of(&b);
    bc.merge_from(&hist_of(&c));
    let right = hist_of(&a);
    right.merge_from(&bc);
    assert_eq!(left.counts(), right.counts(), "associativity");
    assert_eq!(left.count(), (a.len() + b.len() + c.len()) as u64);

    // a ∪ b == b ∪ a
    let ab = hist_of(&a);
    ab.merge_from(&hist_of(&b));
    let ba = hist_of(&b);
    ba.merge_from(&hist_of(&a));
    assert_eq!(ab.counts(), ba.counts(), "commutativity");
}

#[test]
fn hist_quantiles_are_monotone_in_p() {
    for seed in [1u64, 2, 3] {
        let h = hist_of(&seeded_values(seed, 500, 10_000_000));
        let mut last = 0u64;
        for p in [1.0, 5.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0] {
            let q = h.quantile(p).unwrap();
            assert!(q >= last, "seed={seed}: q({p}) = {q} < {last}");
            last = q;
        }
    }
}

#[test]
fn merged_hist_quantiles_track_the_exact_oracle_within_one_bucket() {
    let a = seeded_values(7, 300, 2_000_000);
    let b = seeded_values(8, 200, 900_000_000);
    let merged = hist_of(&a);
    merged.merge_from(&hist_of(&b));

    let mut sorted: Vec<u64> = a.iter().chain(b.iter()).copied().collect();
    sorted.sort_unstable();
    for p in [10.0, 50.0, 90.0, 95.0, 99.0] {
        // same nearest-rank convention as percentile_us, kept in ns so
        // the bound is integer-exact
        let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
        let exact = sorted[rank.clamp(1, sorted.len()) - 1];
        assert!(
            (percentile_us(&sorted, p) * 1_000.0 - exact as f64).abs() < 1e-6,
            "oracle self-check at p={p}"
        );
        let q = merged.quantile(p).unwrap();
        // the log2-bucket floor: never above the sample, never more
        // than one bucket width below it
        assert!(
            q <= exact && exact < (2 * q).max(2),
            "p={p}: bucket floor {q} vs exact {exact}"
        );
    }
}
