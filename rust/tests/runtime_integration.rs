//! Integration tests over real AOT artifacts: load, init, step, eval —
//! the full Rust<->XLA contract. Requires `make artifacts` to have run
//! (tests are skipped, loudly, when artifacts/ is missing so `cargo test`
//! works in a fresh checkout).

use std::collections::BTreeMap;
use std::path::PathBuf;

use quantum_peft::coordinator::events::EventLog;
use quantum_peft::coordinator::trainer::{self, GlueRunSpec, TrainConfig};
use quantum_peft::data::glue;
use quantum_peft::runtime::{HostTensor, Manifest, Runtime, TrainSession};

fn artifacts_dir() -> Option<PathBuf> {
    for cand in ["artifacts", "../artifacts"] {
        let p = PathBuf::from(cand);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    None
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
                return;
            }
        }
    };
}

fn quick_cfg() -> TrainConfig {
    TrainConfig {
        steps: 6,
        lr: 0.01,
        weight_decay: 0.01,
        warmup_frac: 0.1,
        eval_every: 3,
        seed: 0,
        train_examples: 48,
        test_examples: 32,
    }
}

#[test]
fn manifest_covers_all_expected_families() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    for tag in ["enc_pretrain", "enc_lora", "enc_qpeft_pauli",
                "enc_qpeft_taylor", "dec_lora", "vit_qpt_taylor",
                "vit_tn_ttd"] {
        let e = m.get(tag).unwrap();
        assert!(e.init_file.exists(), "{tag} init file missing");
        assert!(e.train_file.exists(), "{tag} train file missing");
        assert!(e.eval_file.exists(), "{tag} eval file missing");
        assert!(e.trainable_param_count > 0);
    }
    // the paper's core claim, as recorded by the build: Pauli Quantum-PEFT
    // uses far fewer adapter params than LoRA on the same model
    let lora = m.get("enc_lora").unwrap().adapter_param_count;
    let qp = m.get("enc_qpeft_pauli").unwrap().adapter_param_count;
    assert!(qp * 5 < lora, "qpeft {qp} vs lora {lora}");
}

#[test]
fn session_init_is_seed_deterministic() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let e = m.get("enc_lora").unwrap();
    let s1 = TrainSession::new(&rt, e, 7).unwrap();
    let s2 = TrainSession::new(&rt, e, 7).unwrap();
    let s3 = TrainSession::new(&rt, e, 8).unwrap();
    let a1 = s1.export_adapters().unwrap();
    let a2 = s2.export_adapters().unwrap();
    let a3 = s3.export_adapters().unwrap();
    for ((n1, t1), (_, t2)) in a1.iter().zip(&a2) {
        assert_eq!(t1, t2, "seed-7 reinit differs at {n1}");
    }
    // different seed must differ in at least one trainable tensor
    assert!(a1.iter().zip(&a3).any(|((_, t1), (_, t3))| t1 != t3));
}

#[test]
fn train_step_decreases_loss_and_preserves_frozen() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let e = m.get("enc_lora").unwrap();
    let mut session = TrainSession::new(&rt, e, 0).unwrap();
    let frozen_before: Vec<HostTensor> = session.frozen.iter()
        .map(|l| HostTensor::from_literal(l).unwrap()).collect();

    let g = quantum_peft::data::grammar::Grammar::new();
    let ds = glue::dataset(&g, glue::Task::Sst2, 0, 16, 24);
    let toks: Vec<Vec<u32>> = ds.iter().map(|x| x.tokens.clone()).collect();
    let labels: Vec<f32> = ds.iter().map(|x| x.label).collect();
    let batch = [
        quantum_peft::runtime::tensors::stack_tokens(&toks),
        HostTensor::f32(vec![16], labels),
    ];
    let mut losses = Vec::new();
    for _ in 0..10 {
        losses.push(session.step(&batch, 0.05, 0.0, &[0.0]).unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(losses.last().unwrap() < losses.first().unwrap(),
            "loss did not decrease: {losses:?}");
    // frozen backbone must be bit-identical after training
    for (before, lit) in frozen_before.iter().zip(&session.frozen) {
        assert_eq!(before, &HostTensor::from_literal(lit).unwrap());
    }
}

#[test]
fn eval_shapes_and_determinism() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let e = m.get("enc_qpeft_taylor").unwrap();
    let session = TrainSession::new(&rt, e, 1).unwrap();
    let g = quantum_peft::data::grammar::Grammar::new();
    let ds = glue::dataset(&g, glue::Task::Rte, 3, 16, 24);
    let toks: Vec<Vec<u32>> = ds.iter().map(|x| x.tokens.clone()).collect();
    let x = quantum_peft::runtime::tensors::stack_tokens(&toks);
    let extras = trainer::default_extras(&session.entry, 0.0, &BTreeMap::new());
    let l1 = session.eval(&x, &extras).unwrap();
    let l2 = session.eval(&x, &extras).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(l1.shape(), &[16, 2]);
}

#[test]
fn k_prime_extra_changes_qpeft_taylor_output() {
    // Table 8's mechanism: the same artifact must respond to the runtime
    // intrinsic-rank mask.
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let e = m.get("vit_qpt_taylor").unwrap();
    let mut session = TrainSession::new(&rt, e, 2).unwrap();
    let imgs = quantum_peft::data::images::dataset(5, 16, true, 0.05);
    let pix: Vec<Vec<f32>> = imgs.iter().map(|i| i.pixels.clone()).collect();
    let labels: Vec<i32> = imgs.iter().map(|i| i.label as i32).collect();
    let batch = [
        quantum_peft::runtime::tensors::stack_f32(&pix, &[16, 16, 3]),
        HostTensor::i32(vec![16], labels),
    ];
    // train a couple steps so lam != 0 (otherwise the adapter is inert)
    let full = trainer::default_extras(&session.entry, 0.0, &BTreeMap::new());
    for _ in 0..3 {
        session.step(&batch, 0.05, 0.0, &full).unwrap();
    }
    let x = batch[0].clone();
    let mut ov = BTreeMap::new();
    ov.insert("k_prime".to_string(), 1.0f32);
    let masked = trainer::default_extras(&session.entry, 0.0, &ov);
    let y_full = session.eval(&x, &full).unwrap();
    let y_masked = session.eval(&x, &masked).unwrap();
    assert_ne!(y_full, y_masked, "K' mask had no effect");
}

#[test]
fn quick_glue_run_end_to_end() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let spec = GlueRunSpec {
        tag: "enc_qpeft_pauli",
        task: glue::Task::Sst2,
        cfg: quick_cfg(),
        backbone: None,
        extras_override: BTreeMap::new(),
    };
    let r = trainer::run_glue(&rt, &m, &spec, &EventLog::null()).unwrap();
    assert!(r.best_metric.is_finite());
    assert!(r.losses.len() == 6);
    assert!(r.adapter_params < 500, "pauli adapters should be tiny");
}

#[test]
fn checkpoint_roundtrip_through_session() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let e = m.get("enc_lora").unwrap();
    let session = TrainSession::new(&rt, e, 3).unwrap();
    let named = session.export_named().unwrap();
    let path = std::env::temp_dir().join("qp_itest_ckpt.qpck");
    quantum_peft::coordinator::checkpoint::save(&path, &named).unwrap();
    let loaded = quantum_peft::coordinator::checkpoint::load(&path).unwrap();
    let mut session2 = TrainSession::new(&rt, e, 99).unwrap();
    let n = session2.load_named(&loaded).unwrap();
    assert_eq!(n, named.len());
    let a = session.export_named().unwrap();
    let b = session2.export_named().unwrap();
    for ((n1, t1), (_, t2)) in a.iter().zip(&b) {
        assert_eq!(t1, t2, "mismatch at {n1}");
    }
}
