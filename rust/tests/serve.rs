//! Integration tests for the multi-tenant adapter serving subsystem
//! (ISSUE 3 acceptance): fifo-mode byte-determinism at any worker count,
//! hot-swap atomicity under 8-worker load, the LRU materialization
//! cache's byte budget and counters end-to-end, and the `serve-bench`
//! loadgen's EventLog summary.

use std::collections::BTreeMap;
use std::sync::Mutex;

use quantum_peft::coordinator::events::EventLog;
use quantum_peft::quantum::pauli;
use quantum_peft::runtime::Runtime;
use quantum_peft::serve::loadgen::{self, response_log};
use quantum_peft::serve::registry::theta_checksum;
use quantum_peft::serve::scheduler::BatchPolicy;
use quantum_peft::serve::{
    BenchOpts, LoadSpec, PauliSpec, Registry, ServeConfig,
};
use quantum_peft::util::json::Json;
use quantum_peft::util::rng::Rng;

#[test]
fn fifo_mode_is_byte_identical_for_any_worker_count() {
    let mk = |workers: usize, seed: u64| {
        let opts = BenchOpts {
            load: LoadSpec {
                tenants: 8,
                requests: 192,
                concurrency: 24,
                seed,
                zipf_s: 1.1,
                pauli: PauliSpec { q: 4, n_layers: 1 },
                open_rate_rps: 0.0,
            },
            serve: ServeConfig {
                workers,
                policy: BatchPolicy { max_batch: 5, max_wait_us: 1 },
                fifo: true,
            },
            cache_bytes: 1 << 20,
        };
        loadgen::run_serve_bench(&opts, &EventLog::null()).unwrap()
    };
    let (s1, log1) = mk(1, 7);
    assert_eq!(s1.completed, 192);
    assert_eq!(s1.failed, 0);
    for workers in [2, 4, 8] {
        let (s, log) = mk(workers, 7);
        assert_eq!(s.completed, 192, "workers={workers}");
        assert_eq!(log, log1, "response log diverged at workers={workers}");
        // batch formation is submission-order-determined too, so even
        // the histogram is reproducible across worker counts
        assert_eq!(s.batch_hist, s1.batch_hist, "workers={workers}");
    }
    // a different seed must actually change the traffic
    let (_, other) = mk(2, 8);
    assert_ne!(other, log1);
}

#[test]
fn hot_swap_under_load_never_tears_version_and_params() {
    const WORKERS: usize = 8;
    const SWAPS: usize = 40;
    const REQS_PER_ROUND: usize = 16;
    let spec = PauliSpec { q: 5, n_layers: 1 };
    let dim = spec.dim();
    let reg = Registry::new(16 << 20);
    let mut root = Rng::new(123);
    let mk_thetas = |rng: &mut Rng| -> Vec<f32> {
        (0..spec.num_params()).map(|_| rng.normal() as f32 * 0.5).collect()
    };
    let v1 = mk_thetas(&mut root);
    reg.register("hot", spec, v1.clone()).unwrap();
    // version -> (checksum, thetas), grown as the swapper publishes
    let published: Mutex<BTreeMap<u64, Vec<f32>>> = Mutex::new(
        [(1u64, v1)].into_iter().collect());

    let rt = Runtime::cpu().unwrap();
    let cfg = ServeConfig {
        workers: WORKERS,
        policy: BatchPolicy { max_batch: 4, max_wait_us: 1 },
        fifo: true,
    };
    let inputs: Mutex<Vec<Vec<f32>>> = Mutex::new(Vec::new());
    let outcome = quantum_peft::serve::serve(
        &rt, &reg, &cfg, &EventLog::null(), |h| {
            let mut responses = Vec::new();
            let mut swap_rng = root.fork(1);
            let mut in_rng = root.fork(2);
            for round in 0..SWAPS {
                let mut handles = Vec::new();
                for k in 0..REQS_PER_ROUND {
                    let input: Vec<f32> = (0..dim)
                        .map(|_| in_rng.normal() as f32 * 0.5)
                        .collect();
                    let meta = (round * REQS_PER_ROUND + k) as u64;
                    inputs.lock().unwrap().push(input.clone());
                    handles.push(h.submit("hot", meta, input)?);
                }
                // swap while this round's batches are in flight on 8
                // workers: each batch serves whichever snapshot it
                // resolves — old or new is fine, a mix never is
                let thetas = mk_thetas(&mut swap_rng);
                let v = reg.register("hot", spec, thetas.clone()).unwrap();
                assert_eq!(v as usize, round + 2);
                published.lock().unwrap().insert(v, thetas);
                h.flush();
                for hd in handles {
                    responses.push(hd.wait()?);
                }
            }
            Ok(responses)
        }).unwrap();

    let published = published.into_inner().unwrap();
    let inputs = inputs.into_inner().unwrap();
    let circuit = pauli::build(5, 1);
    assert_eq!(outcome.body.len(), SWAPS * REQS_PER_ROUND);
    for resp in &outcome.body {
        // (a) the version tag matches the checksum of that version's
        // exact thetas — old params under a new tag would fail here
        let thetas = published.get(&resp.version).unwrap_or_else(|| {
            panic!("response claims unpublished version {}", resp.version)
        });
        assert_eq!(resp.checksum, theta_checksum(thetas),
                   "torn read at version {}", resp.version);
        // (b) the output is exactly x @ Q_P(thetas[version])
        let mut expect = inputs[resp.meta as usize].clone();
        circuit.apply(&mut expect, 1, thetas);
        for (a, b) in resp.output.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4,
                    "output mismatch at version {}: {a} vs {b}", resp.version);
        }
    }
    assert_eq!(outcome.summary.failed, 0);
    // hot-swap must not leak in-flight pins
    assert_eq!(reg.inflight("hot"), 0);
}

#[test]
fn lru_cache_respects_budget_end_to_end() {
    // capacity = exactly two 16x16 f32 matrices; three tenants served
    // strictly sequentially (max_batch 1, one wait per submit) so the
    // hit/miss/eviction sequence is fully deterministic
    let spec = PauliSpec { q: 4, n_layers: 1 };
    let one = 16 * 16 * 4;
    let reg = Registry::new(2 * one);
    for t in ["a", "b", "c"] {
        let thetas: Vec<f32> = (0..spec.num_params())
            .map(|i| (i as f32 * 0.17).sin())
            .collect();
        reg.register(t, spec, thetas).unwrap();
    }
    let rt = Runtime::cpu().unwrap();
    let cfg = ServeConfig {
        workers: 1,
        policy: BatchPolicy { max_batch: 1, max_wait_us: 1 },
        fifo: true,
    };
    quantum_peft::serve::serve(&rt, &reg, &cfg, &EventLog::null(), |h| {
        // a(miss) a(hit) b(miss) c(miss, evicts a) a(miss, evicts b)
        for (i, t) in ["a", "a", "b", "c", "a"].iter().enumerate() {
            h.submit(t, i as u64, vec![0.25; 16])?.wait()?;
        }
        Ok(())
    }).unwrap();
    let s = reg.cache_stats();
    assert_eq!((s.hits, s.misses, s.evictions), (1, 4, 2), "{s:?}");
    assert!(s.bytes <= s.capacity_bytes, "{s:?}");
    assert_eq!(s.entries, 2, "{s:?}");
}

#[test]
fn serve_bench_emits_summary_through_event_log() {
    let path = std::env::temp_dir().join("qp_serve_bench_events.jsonl");
    let _ = std::fs::remove_file(&path);
    let log = EventLog::new(Some(path.clone()), false).unwrap();
    let opts = BenchOpts {
        load: LoadSpec {
            tenants: 4,
            requests: 64,
            concurrency: 16,
            seed: 3,
            zipf_s: 1.0,
            pauli: PauliSpec { q: 3, n_layers: 1 },
            open_rate_rps: 0.0,
        },
        serve: ServeConfig {
            workers: 2,
            policy: BatchPolicy { max_batch: 4, max_wait_us: 50 },
            fifo: true,
        },
        cache_bytes: 1 << 20,
    };
    let (summary, _) = loadgen::run_serve_bench(&opts, &log).unwrap();
    assert_eq!(summary.completed, 64);
    assert!(summary.rps > 0.0);
    let text = std::fs::read_to_string(&path).unwrap();
    let mut kinds: BTreeMap<String, usize> = BTreeMap::new();
    let mut summary_line = None;
    for line in text.lines() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("bad line {line:?}: {e}"));
        let kind = j.get("event").unwrap().as_str().unwrap().to_string();
        if kind == "serve_summary" {
            summary_line = Some(j.clone());
        }
        *kinds.entry(kind).or_insert(0) += 1;
    }
    assert_eq!(kinds.get("serve_bench"), Some(&1), "{kinds:?}");
    assert_eq!(kinds.get("serve_summary"), Some(&1), "{kinds:?}");
    // one line per tenant that saw traffic (Zipf may starve cold ranks)
    let tenant_lines = *kinds.get("serve_tenant").unwrap_or(&0);
    assert!((1..=4).contains(&tenant_lines), "{kinds:?}");
    // per-tenant request counts must account for every request exactly
    let per_tenant_total: usize = text.lines()
        .map(|l| Json::parse(l).unwrap())
        .filter(|j| j.get("event").unwrap().as_str().unwrap() == "serve_tenant")
        .map(|j| j.get("requests").unwrap().as_usize().unwrap())
        .sum();
    assert_eq!(per_tenant_total, 64);
    let s = summary_line.unwrap();
    assert_eq!(s.get("completed").unwrap().as_usize().unwrap(), 64);
    assert!(s.get("rps").unwrap().as_f64().unwrap() > 0.0);
    assert!(s.get("p99_us").unwrap().as_f64().unwrap()
            >= s.get("p50_us").unwrap().as_f64().unwrap());
    // batch histogram is a [[size, count], ...] array summing to the
    // dispatched batches
    let hist = s.get("batch_hist").unwrap().as_arr().unwrap();
    let total: usize = hist.iter()
        .map(|p| p.as_arr().unwrap()[1].as_usize().unwrap())
        .sum();
    assert!(total > 0, "empty batch histogram");
}

#[test]
fn open_loop_timed_mode_completes_all_requests() {
    // open-loop arrivals + timed batching: not byte-deterministic, but
    // every request must complete and the queue must fully drain
    let opts = BenchOpts {
        load: LoadSpec {
            tenants: 3,
            requests: 48,
            concurrency: 1,
            seed: 5,
            zipf_s: 0.5,
            pauli: PauliSpec { q: 3, n_layers: 1 },
            open_rate_rps: 20_000.0,
        },
        serve: ServeConfig {
            workers: 4,
            policy: BatchPolicy { max_batch: 6, max_wait_us: 100 },
            fifo: false,
        },
        cache_bytes: 1 << 20,
    };
    let (summary, log) = loadgen::run_serve_bench(&opts, &EventLog::null()).unwrap();
    assert_eq!(summary.completed, 48);
    assert_eq!(summary.failed, 0);
    assert_eq!(log.lines().count(), 48);
}

#[test]
fn response_log_sorts_by_meta() {
    use quantum_peft::serve::Response;
    let r = |meta: u64| Response {
        meta,
        tenant: "t".into(),
        version: 1,
        checksum: 9,
        output: vec![1.0],
        latency_us: 1.0,
    };
    let log = response_log(&[r(2), r(0), r(1)]);
    let metas: Vec<&str> = log.lines()
        .map(|l| l.split_whitespace().next().unwrap())
        .collect();
    assert_eq!(metas, vec!["meta=0", "meta=1", "meta=2"]);
}
