//! Integration tests for the multi-tenant adapter serving subsystem:
//! fifo-mode byte-determinism at any worker count (ISSUE 3), hot-swap
//! atomicity under 8-worker load, the LRU materialization cache's byte
//! budget and counters end-to-end, the `serve-bench` loadgen's EventLog
//! summary, the ISSUE 4 control plane — deterministic rate-limited
//! overload shedding with per-tenant rejection counters, and
//! spool-directory adapter ingestion (hot upload / quarantine /
//! pin-respecting eviction) with no server restart — the ISSUE 6
//! shard tier: per-shard fifo byte-determinism, zero-drop live tenant
//! migration, and per-shard crash recovery from each shard's own state
//! dir — and the ISSUE 8 observability layer: the log₂-bucket
//! histogram pinned against the exact percentile oracle, fifo
//! `serve_interval`/`serve_trace` byte-identity at any worker count,
//! and the killed-shard flight-recorder dump.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use quantum_peft::coordinator::checkpoint::{save_adapter_atomic, AdapterManifest};
use quantum_peft::coordinator::events::EventLog;
use quantum_peft::obs::Hist;
use quantum_peft::quantum::pauli;
use quantum_peft::runtime::{HostTensor, Runtime};
use quantum_peft::serve::loadgen::{self, response_log};
use quantum_peft::serve::registry::theta_checksum;
use quantum_peft::serve::scheduler::BatchPolicy;
use quantum_peft::serve::{
    percentile_us, AdmissionConfig, BenchOpts, LoadSpec, PauliSpec, Registry,
    RejectReason, Rejected, ServeConfig, ShardConfig, Spool, SpoolConfig,
    SpoolWatcher,
};
use quantum_peft::util::json::Json;
use quantum_peft::util::rng::Rng;

#[test]
fn fifo_mode_is_byte_identical_for_any_worker_count() {
    let mk = |workers: usize, seed: u64| {
        let opts = BenchOpts {
            load: LoadSpec {
                tenants: 8,
                requests: 192,
                concurrency: 24,
                seed,
                zipf_s: 1.1,
                pauli: PauliSpec { q: 4, n_layers: 1 },
                open_rate_rps: 0.0,
            },
            serve: ServeConfig {
                workers,
                policy: BatchPolicy { max_batch: 5, max_wait_us: 1 },
                fifo: true,
                ..ServeConfig::default()
            },
            cache_bytes: 1 << 20,
            ..BenchOpts::default()
        };
        loadgen::run_serve_bench(&opts, &EventLog::null()).unwrap()
    };
    let (s1, log1) = mk(1, 7);
    assert_eq!(s1.completed, 192);
    assert_eq!(s1.failed, 0);
    for workers in [2, 4, 8] {
        let (s, log) = mk(workers, 7);
        assert_eq!(s.completed, 192, "workers={workers}");
        assert_eq!(log, log1, "response log diverged at workers={workers}");
        // batch formation is submission-order-determined too, so even
        // the histogram is reproducible across worker counts
        assert_eq!(s.batch_hist, s1.batch_hist, "workers={workers}");
    }
    // a different seed must actually change the traffic
    let (_, other) = mk(2, 8);
    assert_ne!(other, log1);
}

#[test]
fn hot_swap_under_load_never_tears_version_and_params() {
    const WORKERS: usize = 8;
    const SWAPS: usize = 40;
    const REQS_PER_ROUND: usize = 16;
    let spec = PauliSpec { q: 5, n_layers: 1 };
    let dim = spec.dim();
    let reg = Registry::new(16 << 20);
    let mut root = Rng::new(123);
    let mk_thetas = |rng: &mut Rng| -> Vec<f32> {
        (0..spec.num_params()).map(|_| rng.normal() as f32 * 0.5).collect()
    };
    let v1 = mk_thetas(&mut root);
    reg.register("hot", spec, v1.clone()).unwrap();
    // version -> (checksum, thetas), grown as the swapper publishes
    let published: Mutex<BTreeMap<u64, Vec<f32>>> = Mutex::new(
        [(1u64, v1)].into_iter().collect());

    let rt = Runtime::cpu().unwrap();
    let cfg = ServeConfig {
        workers: WORKERS,
        policy: BatchPolicy { max_batch: 4, max_wait_us: 1 },
        fifo: true,
        ..ServeConfig::default()
    };
    let inputs: Mutex<Vec<Vec<f32>>> = Mutex::new(Vec::new());
    let outcome = quantum_peft::serve::serve(
        &rt, &reg, &cfg, &EventLog::null(), |h| {
            let mut responses = Vec::new();
            let mut swap_rng = root.fork(1);
            let mut in_rng = root.fork(2);
            for round in 0..SWAPS {
                let mut handles = Vec::new();
                for k in 0..REQS_PER_ROUND {
                    let input: Vec<f32> = (0..dim)
                        .map(|_| in_rng.normal() as f32 * 0.5)
                        .collect();
                    let meta = (round * REQS_PER_ROUND + k) as u64;
                    inputs.lock().unwrap().push(input.clone());
                    handles.push(h.submit("hot", meta, input)?);
                }
                // swap while this round's batches are in flight on 8
                // workers: each batch serves whichever snapshot it
                // resolves — old or new is fine, a mix never is
                let thetas = mk_thetas(&mut swap_rng);
                let v = reg.register("hot", spec, thetas.clone()).unwrap();
                assert_eq!(v as usize, round + 2);
                published.lock().unwrap().insert(v, thetas);
                h.flush();
                for hd in handles {
                    responses.push(hd.wait()?);
                }
            }
            Ok(responses)
        }).unwrap();

    let published = published.into_inner().unwrap();
    let inputs = inputs.into_inner().unwrap();
    let circuit = pauli::build(5, 1);
    assert_eq!(outcome.body.len(), SWAPS * REQS_PER_ROUND);
    for resp in &outcome.body {
        // (a) the version tag matches the checksum of that version's
        // exact thetas — old params under a new tag would fail here
        let thetas = published.get(&resp.version).unwrap_or_else(|| {
            panic!("response claims unpublished version {}", resp.version)
        });
        assert_eq!(resp.checksum, theta_checksum(thetas),
                   "torn read at version {}", resp.version);
        // (b) the output is exactly x @ Q_P(thetas[version])
        let mut expect = inputs[resp.meta as usize].clone();
        circuit.apply(&mut expect, 1, thetas);
        for (a, b) in resp.output.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4,
                    "output mismatch at version {}: {a} vs {b}", resp.version);
        }
    }
    assert_eq!(outcome.summary.failed, 0);
    // hot-swap must not leak in-flight pins
    assert_eq!(reg.inflight("hot"), 0);
}

#[test]
fn lru_cache_respects_budget_end_to_end() {
    // capacity = exactly two 16x16 f32 matrices; three tenants served
    // strictly sequentially (max_batch 1, one wait per submit) so the
    // hit/miss/eviction sequence is fully deterministic
    let spec = PauliSpec { q: 4, n_layers: 1 };
    let one = 16 * 16 * 4;
    let reg = Registry::new(2 * one);
    for t in ["a", "b", "c"] {
        let thetas: Vec<f32> = (0..spec.num_params())
            .map(|i| (i as f32 * 0.17).sin())
            .collect();
        reg.register(t, spec, thetas).unwrap();
    }
    let rt = Runtime::cpu().unwrap();
    let cfg = ServeConfig {
        workers: 1,
        policy: BatchPolicy { max_batch: 1, max_wait_us: 1 },
        fifo: true,
        ..ServeConfig::default()
    };
    quantum_peft::serve::serve(&rt, &reg, &cfg, &EventLog::null(), |h| {
        // a(miss) a(hit) b(miss) c(miss, evicts a) a(miss, evicts b)
        for (i, t) in ["a", "a", "b", "c", "a"].iter().enumerate() {
            h.submit(t, i as u64, vec![0.25; 16])?.wait()?;
        }
        Ok(())
    }).unwrap();
    let s = reg.cache_stats();
    assert_eq!((s.hits, s.misses, s.evictions), (1, 4, 2), "{s:?}");
    assert!(s.bytes <= s.capacity_bytes, "{s:?}");
    assert_eq!(s.entries, 2, "{s:?}");
}

#[test]
fn serve_bench_emits_summary_through_event_log() {
    let path = std::env::temp_dir().join("qp_serve_bench_events.jsonl");
    let _ = std::fs::remove_file(&path);
    let log = EventLog::new(Some(path.clone()), false).unwrap();
    let opts = BenchOpts {
        load: LoadSpec {
            tenants: 4,
            requests: 64,
            concurrency: 16,
            seed: 3,
            zipf_s: 1.0,
            pauli: PauliSpec { q: 3, n_layers: 1 },
            open_rate_rps: 0.0,
        },
        serve: ServeConfig {
            workers: 2,
            policy: BatchPolicy { max_batch: 4, max_wait_us: 50 },
            fifo: true,
            ..ServeConfig::default()
        },
        cache_bytes: 1 << 20,
        ..BenchOpts::default()
    };
    let (summary, _) = loadgen::run_serve_bench(&opts, &log).unwrap();
    assert_eq!(summary.completed, 64);
    assert!(summary.rps > 0.0);
    let text = std::fs::read_to_string(&path).unwrap();
    let mut kinds: BTreeMap<String, usize> = BTreeMap::new();
    let mut summary_line = None;
    for line in text.lines() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("bad line {line:?}: {e}"));
        let kind = j.get("event").unwrap().as_str().unwrap().to_string();
        if kind == "serve_summary" {
            summary_line = Some(j.clone());
        }
        *kinds.entry(kind).or_insert(0) += 1;
    }
    assert_eq!(kinds.get("serve_bench"), Some(&1), "{kinds:?}");
    assert_eq!(kinds.get("serve_summary"), Some(&1), "{kinds:?}");
    // one line per tenant that saw traffic (Zipf may starve cold ranks)
    let tenant_lines = *kinds.get("serve_tenant").unwrap_or(&0);
    assert!((1..=4).contains(&tenant_lines), "{kinds:?}");
    // per-tenant request counts must account for every request exactly
    let per_tenant_total: usize = text.lines()
        .map(|l| Json::parse(l).unwrap())
        .filter(|j| j.get("event").unwrap().as_str().unwrap() == "serve_tenant")
        .map(|j| j.get("requests").unwrap().as_usize().unwrap())
        .sum();
    assert_eq!(per_tenant_total, 64);
    let s = summary_line.unwrap();
    assert_eq!(s.get("completed").unwrap().as_usize().unwrap(), 64);
    assert!(s.get("rps").unwrap().as_f64().unwrap() > 0.0);
    assert!(s.get("p99_us").unwrap().as_f64().unwrap()
            >= s.get("p50_us").unwrap().as_f64().unwrap());
    // batch histogram is a [[size, count], ...] array summing to the
    // dispatched batches
    let hist = s.get("batch_hist").unwrap().as_arr().unwrap();
    let total: usize = hist.iter()
        .map(|p| p.as_arr().unwrap()[1].as_usize().unwrap())
        .sum();
    assert!(total > 0, "empty batch histogram");
}

#[test]
fn open_loop_timed_mode_completes_all_requests() {
    // open-loop arrivals + timed batching: not byte-deterministic, but
    // every request must complete and the queue must fully drain
    let opts = BenchOpts {
        load: LoadSpec {
            tenants: 3,
            requests: 48,
            concurrency: 1,
            seed: 5,
            zipf_s: 0.5,
            pauli: PauliSpec { q: 3, n_layers: 1 },
            open_rate_rps: 20_000.0,
        },
        serve: ServeConfig {
            workers: 4,
            policy: BatchPolicy { max_batch: 6, max_wait_us: 100 },
            fifo: false,
            ..ServeConfig::default()
        },
        cache_bytes: 1 << 20,
        ..BenchOpts::default()
    };
    let (summary, log) = loadgen::run_serve_bench(&opts, &EventLog::null()).unwrap();
    assert_eq!(summary.completed, 48);
    assert_eq!(summary.failed, 0);
    assert_eq!(log.lines().count(), 48);
}

// ------------------------------------------------------------ admission ---

fn overload_opts(workers: usize) -> BenchOpts {
    BenchOpts {
        load: LoadSpec {
            tenants: 8,
            requests: 400,
            concurrency: 1,
            seed: 11,
            zipf_s: 1.2,
            pauli: PauliSpec { q: 4, n_layers: 1 },
            // open loop at ~5x the aggregate admitted budget: a true
            // overload, but in fifo mode the gaps advance the logical
            // clock instead of sleeping, so the run is instant and
            // deterministic
            open_rate_rps: 2000.0,
        },
        serve: ServeConfig {
            workers,
            policy: BatchPolicy { max_batch: 4, max_wait_us: 1 },
            fifo: true,
            admission: AdmissionConfig { rate_rps: 50.0, burst: 5.0, max_queue: 0 },
            ..ServeConfig::default()
        },
        cache_bytes: 1 << 20,
        ..BenchOpts::default()
    }
}

fn tenant_rejections(
    s: &quantum_peft::serve::ServeSummary, tenant: &str,
) -> u64 {
    s.admission.per_tenant.iter()
        .find(|t| t.tenant == tenant)
        .map(|t| t.rejected_rate_limited + t.rejected_queue_full)
        .unwrap_or(0)
}

#[test]
fn rate_limited_overload_sheds_deterministically_at_any_worker_count() {
    let (s1, log1) =
        loadgen::run_serve_bench(&overload_opts(1), &EventLog::null()).unwrap();
    // a real overload: something was shed, everything admitted completed,
    // and the ledger closes exactly
    assert!(s1.admission.rejected_rate_limited > 0, "{:?}", s1.admission);
    assert_eq!(s1.admission.rejected_queue_full, 0);
    assert_eq!(s1.completed, s1.admission.admitted);
    assert_eq!(s1.admission.admitted + s1.admission.rejected_total(), 400);
    // Zipf skew makes the hottest tenant blow its budget hardest
    let hot = tenant_rejections(&s1, &loadgen::tenant_name(0));
    let cold = tenant_rejections(&s1, &loadgen::tenant_name(7));
    assert!(hot > cold, "hot {hot} vs cold {cold}");
    // fifo byte-identity now covers rejections too: same response log,
    // same admission ledger, at any worker count
    for workers in [4, 8] {
        let (s, log) = loadgen::run_serve_bench(
            &overload_opts(workers), &EventLog::null()).unwrap();
        assert_eq!(log, log1, "response log diverged at workers={workers}");
        assert_eq!(s.admission, s1.admission,
                   "admission ledger diverged at workers={workers}");
    }
}

#[test]
fn admission_counters_land_in_the_event_log_per_tenant() {
    let path = std::env::temp_dir().join(format!(
        "qp_serve_admission_events_{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let log = EventLog::new(Some(path.clone()), false).unwrap();
    let (summary, _) =
        loadgen::run_serve_bench(&overload_opts(2), &log).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    let global: Vec<&Json> = lines.iter()
        .filter(|j| j.get("event").unwrap().as_str().unwrap() == "serve_admission")
        .collect();
    assert_eq!(global.len(), 1);
    let g = global[0];
    assert_eq!(g.get("rejected_rate_limited").unwrap().as_usize().unwrap() as u64,
               summary.admission.rejected_rate_limited);
    assert_eq!(g.get("admitted").unwrap().as_usize().unwrap() as u64,
               summary.admission.admitted);
    // per-tenant lines account for every rejection exactly
    let per_tenant: Vec<&Json> = lines.iter()
        .filter(|j| {
            j.get("event").unwrap().as_str().unwrap() == "serve_admission_tenant"
        })
        .collect();
    assert!(!per_tenant.is_empty());
    let rejected_sum: usize = per_tenant.iter()
        .map(|j| j.get("rejected_rate_limited").unwrap().as_usize().unwrap())
        .sum();
    assert_eq!(rejected_sum as u64, summary.admission.rejected_rate_limited);
    let admitted_sum: usize = per_tenant.iter()
        .map(|j| j.get("admitted").unwrap().as_usize().unwrap())
        .sum();
    assert_eq!(admitted_sum as u64, summary.admission.admitted);
}

// ---------------------------------------------------------------- spool ---

fn spool_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join("qp_spool_e2e")
        .join(format!("{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn adapter_thetas(spec: PauliSpec, salt: f32) -> Vec<f32> {
    (0..spec.num_params()).map(|i| (i as f32 * salt).sin()).collect()
}

fn write_adapter(dir: &std::path::Path, file: &str, tenant: &str,
                 spec: PauliSpec, thetas: &[f32]) {
    let m = AdapterManifest {
        tenant: tenant.into(), q: spec.q, n_layers: spec.n_layers,
    };
    save_adapter_atomic(&dir.join(file), &m, &[(
        "thetas".to_string(),
        HostTensor::f32(vec![thetas.len()], thetas.to_vec()),
    )])
    .unwrap();
}

#[test]
fn spool_upload_becomes_servable_with_no_restart() {
    let dir = spool_dir("servable");
    let reg = Arc::new(Registry::new(1 << 20));
    let mut spool =
        Spool::new(reg.clone(), &SpoolConfig::new(&dir), EventLog::null()).unwrap();
    let rt = Runtime::cpu().unwrap();
    let cfg = ServeConfig { workers: 2, ..ServeConfig::default() };
    let spec = PauliSpec { q: 3, n_layers: 1 };
    let thetas = adapter_thetas(spec, 0.29);
    let input: Vec<f32> = (0..8).map(|i| (i as f32 * 0.41).cos()).collect();
    let outcome = quantum_peft::serve::serve(
        &rt, &reg, &cfg, &EventLog::null(), |h| {
            // before the upload the tenant does not exist
            assert!(h.submit("acme", 0, input.clone()).is_err());
            // drop the adapter into the spool mid-session; two polls
            // (stability window) later it serves — no restart, no
            // re-registration API
            write_adapter(&dir, "acme.qpck", "acme", spec, &thetas);
            spool.poll();
            spool.poll();
            let r = h.submit("acme", 1, input.clone())?;
            h.flush();
            r.wait()
        })
        .unwrap();
    let resp = outcome.body;
    assert_eq!((resp.tenant.as_str(), resp.version), ("acme", 1));
    assert_eq!(resp.checksum, theta_checksum(&thetas));
    let mut expect = input.clone();
    pauli::build(3, 1).apply(&mut expect, 1, &thetas);
    for (a, b) in resp.output.iter().zip(&expect) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
    assert_eq!(spool.stats().loaded, 1);
    // the ingested file is still in place under its public name
    assert!(dir.join("acme.qpck").exists());
}

#[test]
fn spool_quarantines_malformed_files_without_touching_the_registry() {
    let dir = spool_dir("quarantine");
    let reg = Arc::new(Registry::new(1 << 20));
    let mut spool =
        Spool::new(reg.clone(), &SpoolConfig::new(&dir), EventLog::null()).unwrap();
    // a truncated/hostile header and a v1 checkpoint with no manifest:
    // both must fail validation, not register anything
    std::fs::write(dir.join("evil.qpck"), b"QPCK\x02garbage-truncated").unwrap();
    quantum_peft::coordinator::checkpoint::save(
        &dir.join("v1.qpck"),
        &[("thetas".to_string(), HostTensor::f32(vec![2], vec![0.0; 2]))])
        .unwrap();
    spool.poll(); // arm stability window
    let s = spool.poll(); // ingest -> reject both
    assert_eq!(s.rejected, 2, "{s:?}");
    assert_eq!(s.loaded, 0);
    assert!(reg.is_empty(), "hostile file mutated the registry");
    // quarantined out of the spool, present under rejected/
    assert!(!dir.join("evil.qpck").exists());
    assert!(!dir.join("v1.qpck").exists());
    assert!(dir.join("rejected").join("evil.qpck").exists());
    assert!(dir.join("rejected").join("v1.qpck").exists());
    // never retried: further polls change nothing
    let s = spool.poll();
    assert_eq!((s.rejected, s.loaded), (2, 0), "{s:?}");
}

#[test]
fn spool_deletion_evicts_only_after_inflight_pins_drain() {
    let dir = spool_dir("evict");
    let reg = Arc::new(Registry::new(1 << 20));
    let mut spool =
        Spool::new(reg.clone(), &SpoolConfig::new(&dir), EventLog::null()).unwrap();
    let spec = PauliSpec { q: 3, n_layers: 1 };
    write_adapter(&dir, "acme.qpck", "acme", spec, &adapter_thetas(spec, 0.31));
    spool.poll();
    spool.poll();
    assert_eq!(reg.snapshot("acme").unwrap().version, 1);
    // an in-flight request pins the tenant across the file deletion
    let guard = reg.begin("acme").unwrap();
    std::fs::remove_file(dir.join("acme.qpck")).unwrap();
    let s = spool.poll();
    assert_eq!(s.evicted, 0, "{s:?}");
    assert!(s.eviction_deferred >= 1, "{s:?}");
    assert!(reg.snapshot("acme").is_ok(), "evicted under an in-flight pin");
    spool.poll();
    assert!(reg.snapshot("acme").is_ok());
    // pin drains -> the deferred eviction lands on the next poll
    drop(guard);
    let s = spool.poll();
    assert_eq!(s.evicted, 1, "{s:?}");
    assert!(reg.snapshot("acme").is_err());
    assert_eq!(reg.len(), 0);
}

#[test]
fn spool_quarantines_payload_checksum_mismatch_with_reason() {
    // a structurally valid v3 upload whose theta payload was corrupted
    // in transit: the whole-payload checksum rejects it at load, the
    // spool quarantines it, and the registry is never touched
    let dir = spool_dir("cksum");
    let reg = Arc::new(Registry::new(1 << 20));
    let path = std::env::temp_dir().join(format!(
        "qp_spool_cksum_events_{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let log = EventLog::new(Some(path.clone()), false).unwrap();
    let mut spool =
        Spool::new(reg.clone(), &SpoolConfig::new(&dir), log).unwrap();
    let spec = PauliSpec { q: 3, n_layers: 1 };
    write_adapter(&dir, "acme.qpck", "acme", spec, &adapter_thetas(spec, 0.23));
    let file = dir.join("acme.qpck");
    let mut bytes = std::fs::read(&file).unwrap();
    let pos = bytes.len() - 12; // inside the theta payload
    bytes[pos] ^= 0x40;
    std::fs::write(&file, &bytes).unwrap();
    spool.poll();
    let s = spool.poll();
    assert_eq!((s.loaded, s.rejected), (0, 1), "{s:?}");
    assert!(reg.is_empty(), "corrupt upload mutated the registry");
    assert!(dir.join("rejected").join("acme.qpck").exists());
    // the logged rejection names the checksum as the reason
    let text = std::fs::read_to_string(&path).unwrap();
    let reject = text.lines()
        .map(|l| Json::parse(l).unwrap())
        .find(|j| j.get("event").unwrap().as_str().unwrap() == "serve_spool_reject")
        .expect("no serve_spool_reject line");
    let reason = reject.get("error").unwrap().as_str().unwrap().to_string();
    assert!(reason.contains("payload checksum mismatch"), "{reason}");
}

#[test]
fn admission_config_hot_reload_lifts_limits_mid_session() {
    use std::time::{Duration, Instant};
    let dir = spool_dir("admission_reload");
    let cfg_path = dir.join("admission.json");
    // start with a hard rate limit: one admission, then rejects (the
    // logical clock never advances, so the bucket never refills)
    std::fs::write(&cfg_path, r#"{"rate_rps": 0.000001, "burst": 1}"#).unwrap();
    // the startup flow main.rs uses: read the file (recording its
    // signature as the reload baseline) and configure from it
    let (spec, text) =
        quantum_peft::serve::AdmissionReloadSpec::read(&cfg_path).unwrap();
    let initial = AdmissionConfig::from_json(&text).unwrap();
    assert_eq!(initial.burst, 1.0);
    let reg = test_registry_q3();
    let rt = Runtime::cpu().unwrap();
    let cfg = ServeConfig {
        workers: 1,
        admission: initial,
        admission_reload: Some(spec),
        ..ServeConfig::default()
    };
    quantum_peft::serve::serve(&rt, &reg, &cfg, &EventLog::null(), |h| {
        let r = h.submit("t0", 0, vec![0.25; 8])?;
        h.flush();
        r.wait()?;
        // the bucket is empty now and stays empty under this config
        assert!(h.submit("t0", 1, vec![0.25; 8]).is_err());
        // lift the limits live: after the watcher's stability window
        // the same tenant admits again, with no restart and without the
        // first response having been disturbed
        std::fs::write(&cfg_path, "{}").unwrap();
        let t0 = Instant::now();
        loop {
            match h.submit("t0", 2, vec![0.25; 8]) {
                Ok(r) => {
                    h.flush();
                    r.wait()?;
                    break;
                }
                Err(_) if t0.elapsed() < Duration::from_secs(10) => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e),
            }
        }
        Ok(())
    })
    .unwrap();
}

/// Registry with one q=3 tenant "t0" (dim 8), for the reload test.
fn test_registry_q3() -> Registry {
    let reg = Registry::new(1 << 22);
    let spec = PauliSpec { q: 3, n_layers: 1 };
    let thetas: Vec<f32> = (0..spec.num_params())
        .map(|i| (i as f32 * 0.37).sin())
        .collect();
    reg.register("t0", spec, thetas).unwrap();
    reg
}

#[test]
fn spool_watcher_ingests_in_background_and_joins_on_shutdown() {
    use std::time::{Duration, Instant};
    let dir = spool_dir("watcher");
    let reg = Arc::new(Registry::new(1 << 20));
    let watcher = SpoolWatcher::start(
        reg.clone(),
        SpoolConfig { dir: dir.clone(), poll_interval: Duration::from_millis(2) },
        EventLog::null())
        .unwrap();
    let spec = PauliSpec { q: 3, n_layers: 1 };
    write_adapter(&dir, "bg.qpck", "bg-tenant", spec, &adapter_thetas(spec, 0.37));
    let t0 = Instant::now();
    while watcher.stats().loaded < 1 && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(watcher.stats().loaded >= 1, "watcher never ingested the upload");
    assert_eq!(reg.snapshot("bg-tenant").unwrap().version, 1);
    // shutdown joins the poller; the registry stays as the watcher left it
    watcher.shutdown();
    assert_eq!(reg.len(), 1);
}

// ---------------------------------------------------------- shard tier ---

#[test]
fn sharded_fifo_per_shard_logs_are_byte_identical_at_any_worker_count() {
    let mk = |workers: usize, seed: u64| {
        let opts = BenchOpts {
            load: LoadSpec {
                tenants: 16,
                requests: 192,
                concurrency: 24,
                seed,
                zipf_s: 1.1,
                pauli: PauliSpec { q: 4, n_layers: 1 },
                open_rate_rps: 0.0,
            },
            serve: ServeConfig {
                workers,
                policy: BatchPolicy { max_batch: 5, max_wait_us: 1 },
                fifo: true,
                ..ServeConfig::default()
            },
            cache_bytes: 1 << 20,
            ..BenchOpts::default()
        };
        loadgen::run_sharded_bench(&opts, 4, &EventLog::null()).unwrap()
    };
    let base = mk(1, 7);
    assert_eq!(base.fleet.completed(), 192);
    assert_eq!(base.fleet.failed(), 0);
    assert_eq!(base.fleet.sessions.len(), 4);
    assert_eq!(base.shard_logs.len(), 4);
    // 16 tenants on a 4-shard ring: traffic must spread past one shard
    let busy = base.shard_logs.iter().filter(|l| !l.is_empty()).count();
    assert!(busy >= 2, "only {busy} shard(s) saw traffic");
    for workers in [4, 8] {
        let r = mk(workers, 7);
        assert_eq!(r.fleet.completed(), 192, "workers={workers}");
        for (s, (a, b)) in
            base.shard_logs.iter().zip(&r.shard_logs).enumerate()
        {
            assert_eq!(a, b, "shard {s} log diverged at workers={workers}");
        }
        assert_eq!(r.merged_log, base.merged_log,
                   "merged log diverged at workers={workers}");
    }
    // a different seed must actually change the traffic
    let other = mk(2, 8);
    assert_ne!(other.merged_log, base.merged_log);
}

#[test]
fn live_migration_drops_nothing_and_keeps_the_merged_log_byte_identical() {
    let spec = PauliSpec { q: 4, n_layers: 1 };
    let n_tenants = 6usize;
    let reqs = 120u64;
    let wave = 12usize;
    let input_for = |meta: u64| -> Vec<f32> {
        (0..spec.dim())
            .map(|j| ((meta as usize * 31 + j) as f32 * 0.13).sin())
            .collect()
    };
    let run = |migrate_at: Option<u64>| -> String {
        let cfg = ShardConfig {
            shards: 3,
            serve: ServeConfig {
                workers: 4,
                policy: BatchPolicy { max_batch: 4, max_wait_us: 1 },
                fifo: true,
                ..ServeConfig::default()
            },
            cache_bytes: 1 << 20,
            ..ShardConfig::default()
        };
        let rt = Runtime::cpu().unwrap();
        let load = LoadSpec {
            tenants: n_tenants, pauli: spec, seed: 42, ..LoadSpec::default()
        };
        let outcome = quantum_peft::serve::serve_sharded(
            &rt, &cfg, &EventLog::null(), |router| {
                loadgen::populate_sharded(router, &load)?;
                let hot = loadgen::tenant_name(0);
                let source = router.shard_of(&hot);
                let mut responses = Vec::new();
                let mut handles = Vec::new();
                for meta in 0..reqs {
                    let t = loadgen::tenant_name(meta as usize % n_tenants);
                    handles.push(router.submit(&t, meta, input_for(meta))?);
                    if migrate_at == Some(meta) {
                        // migrate the hot tenant while un-dispatched
                        // requests of its own still sit in the source
                        // shard's batcher (metas 48 and 54 below)
                        let target = (source + 1) % 3;
                        router.migrate(&hot, target)?;
                        assert_eq!(router.shard_of(&hot), target);
                        // the source pin-drained and forgot the tenant;
                        // the target serves it at the recorded version
                        assert!(router.registry(source)?
                                    .snapshot(&hot).is_err());
                        assert_eq!(router.registry(target)?
                                       .snapshot(&hot)?.version, 1);
                    }
                    if handles.len() == wave {
                        router.flush();
                        for h in handles.drain(..) {
                            responses.push(h.wait()?);
                        }
                    }
                }
                router.flush();
                for h in handles {
                    responses.push(h.wait()?);
                }
                Ok(responses)
            })
            .unwrap();
        response_log(&outcome.body)
    };
    // migrate right after submitting meta 57: the current wave started
    // at 48, so tenant0000's metas 48 and 54 are in flight on the source
    // when the routing table flips
    let control = run(None);
    let migrated = run(Some(57));
    assert_eq!(control.lines().count(), reqs as usize,
               "the control run dropped a request");
    assert_eq!(migrated.lines().count(), reqs as usize,
               "migration dropped an in-flight request");
    assert_eq!(migrated, control, "migration changed the served bytes");
}

#[test]
fn a_killed_shard_recovers_its_own_tenants_while_the_rest_keep_serving() {
    let dir = std::env::temp_dir().join(format!(
        "qp_shard_recover_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = PauliSpec { q: 3, n_layers: 1 };
    let n_tenants = 12usize;
    let cfg = ShardConfig {
        shards: 4,
        serve: ServeConfig {
            workers: 2,
            policy: BatchPolicy { max_batch: 2, max_wait_us: 1 },
            fifo: true,
            ..ServeConfig::default()
        },
        cache_bytes: 1 << 20,
        state_root: Some(dir.clone()),
        ..ShardConfig::default()
    };
    let rt = Runtime::cpu().unwrap();
    let load = LoadSpec {
        tenants: n_tenants, pauli: spec, seed: 9, ..LoadSpec::default()
    };
    quantum_peft::serve::serve_sharded(
        &rt, &cfg, &EventLog::null(), |router| {
            let checksums = loadgen::populate_sharded(router, &load)?;
            // one served round so every tenant proves servable pre-kill
            let mut handles = Vec::new();
            for i in 0..n_tenants {
                handles.push(router.submit(
                    &loadgen::tenant_name(i), i as u64,
                    vec![0.25; spec.dim()])?);
            }
            router.flush();
            for h in handles {
                h.wait()?;
            }
            // the victim: whatever shard the hottest tenant lives on
            let victim = router.shard_of(&loadgen::tenant_name(0));
            let victim_idx: Vec<usize> = (0..n_tenants)
                .filter(|&i| {
                    router.shard_of(&loadgen::tenant_name(i)) == victim
                })
                .collect();
            let mut victim_tenants: Vec<String> =
                victim_idx.iter().map(|&i| loadgen::tenant_name(i)).collect();
            let survivor = (0..n_tenants)
                .map(loadgen::tenant_name)
                .find(|t| router.shard_of(t) != victim)
                .expect("12 tenants on 4 shards leave a survivor");
            router.kill_shard(victim)?;
            assert!(!router.is_alive(victim));
            assert!(router.registry(victim).is_err());
            // the dead shard's tenants shed with the typed reason...
            for t in &victim_tenants {
                let err = router.submit(t, 1000, vec![0.25; spec.dim()])
                    .unwrap_err();
                let rej = err.downcast_ref::<Rejected>()
                    .unwrap_or_else(|| panic!("untyped shed: {err}"));
                assert!(matches!(rej.reason, RejectReason::ShardDown),
                        "{:?}", rej.reason);
                assert_eq!(&rej.tenant, t);
            }
            // ...while every other shard keeps serving
            let h = router.submit(&survivor, 2000, vec![0.25; spec.dim()])?;
            router.flush();
            h.wait()?;
            // restart from the shard's *own* state dir: it recovers
            // exactly the tenants it owned, nothing more
            let mut recovered = router.restart_shard(victim)?;
            recovered.sort();
            victim_tenants.sort();
            assert_eq!(recovered, victim_tenants);
            assert!(router.is_alive(victim));
            // recovered tenants serve at their recorded version with the
            // exact thetas populate registered
            for &i in &victim_idx {
                let h = router.submit(
                    &loadgen::tenant_name(i), 3000 + i as u64,
                    vec![0.25; spec.dim()])?;
                router.flush();
                let r = h.wait()?;
                assert_eq!(r.version, 1,
                           "tenant {i} re-registered instead of restored");
                assert_eq!(r.checksum, checksums[i], "tenant {i}");
            }
            Ok(())
        })
        .unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------- observability ---

#[test]
fn hist_quantiles_track_the_exact_percentile_oracle() {
    // the log₂-bucket histogram that replaced the per-tenant latency
    // vectors must stay within one bucket width of the exact
    // nearest-rank oracle: floor <= exact < max(2*floor, 2ns)
    let ns: Vec<u64> =
        (1..=2000u64).map(|i| (i * i * 2_654_435_761) % 50_000_000).collect();
    let h = Hist::new();
    for &v in &ns {
        h.record(v);
    }
    let mut sorted = ns.clone();
    sorted.sort_unstable();
    for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
        let exact_us = percentile_us(&sorted, p);
        let q_us = h.quantile_us(p).unwrap();
        assert!(q_us <= exact_us + 1e-12, "p{p}: hist {q_us} > exact {exact_us}");
        let upper = (2.0 * q_us).max(0.002);
        assert!(exact_us < upper + 1e-12,
                "p{p}: exact {exact_us} outside [{q_us}, {upper})");
    }
}

#[test]
fn fifo_interval_and_trace_lines_are_byte_identical_across_worker_counts() {
    // the full observable log — serve_interval snapshots, serve_trace
    // spans, serve_slo and per-tenant lines — joins the fifo
    // byte-identity guarantee once the wall-clock ts field is stripped
    // and the two lines that legitimately echo the worker count
    // (serve_bench config, serve_summary wall-clock rps) are dropped
    let run = |workers: usize| -> String {
        let path = std::env::temp_dir().join(format!(
            "qp_serve_obs_events_{}_{workers}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let log = EventLog::new(Some(path.clone()), false).unwrap();
        let opts = BenchOpts {
            load: LoadSpec {
                tenants: 8,
                requests: 192,
                concurrency: 24,
                seed: 7,
                zipf_s: 1.1,
                pauli: PauliSpec { q: 4, n_layers: 1 },
                open_rate_rps: 0.0,
            },
            serve: ServeConfig {
                workers,
                policy: BatchPolicy { max_batch: 5, max_wait_us: 1 },
                fifo: true,
                metrics_interval: 64,
                slo_p99_us: 50.0,
                slo_error_budget: 0.25,
                ..ServeConfig::default()
            },
            cache_bytes: 1 << 20,
            ..BenchOpts::default()
        };
        let (summary, _) = loadgen::run_serve_bench(&opts, &log).unwrap();
        assert_eq!(summary.completed, 192, "workers={workers}");
        // fifo latencies are logical zeros: the SLO budget never burns
        let slo = summary.slo.as_ref().expect("slo section");
        assert_eq!(slo.breached(), 0, "workers={workers}");
        drop(log);
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let (mut intervals, mut traces, mut slo_lines) = (0, 0, 0);
        let mut kept = Vec::new();
        for line in text.lines() {
            let mut j = Json::parse(line).unwrap();
            let ev = j.get("event").unwrap().as_str().unwrap().to_string();
            match ev.as_str() {
                "serve_bench" => continue,
                "serve_summary" => {
                    // the summary line carries the widened schema tag
                    assert_eq!(j.get("schema").unwrap().as_usize().unwrap(), 2);
                    continue;
                }
                "serve_interval" => intervals += 1,
                "serve_trace" => traces += 1,
                "serve_slo" => slo_lines += 1,
                _ => {}
            }
            if let Json::Obj(map) = &mut j {
                map.remove("ts");
            }
            kept.push(j.dump());
        }
        // 192 completions at interval 64, ticked at wave boundaries
        assert!(intervals >= 2, "workers={workers}: {intervals} snapshot(s)");
        // the default recorder cap retains every span of this run
        assert_eq!(traces, 192, "workers={workers}");
        assert!(slo_lines >= 1, "workers={workers}");
        kept.join("\n")
    };
    let base = run(1);
    for workers in [4, 8] {
        assert_eq!(run(workers), base,
                   "observable log diverged at workers={workers}");
    }
}

#[test]
fn a_killed_shard_dumps_its_retained_trace_spans() {
    // kill_shard ends the victim's serve session, and a session end
    // dumps the flight recorders: the victim's spans must be on disk
    // (and only the victim's — the survivors are still serving)
    let path = std::env::temp_dir().join(format!(
        "qp_shard_trace_dump_{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let log = EventLog::new(Some(path.clone()), false).unwrap();
    let spec = PauliSpec { q: 3, n_layers: 1 };
    let n_tenants = 4usize;
    let cfg = ShardConfig {
        shards: 2,
        serve: ServeConfig {
            workers: 1,
            policy: BatchPolicy { max_batch: 2, max_wait_us: 1 },
            fifo: true,
            ..ServeConfig::default()
        },
        cache_bytes: 1 << 20,
        ..ShardConfig::default()
    };
    let rt = Runtime::cpu().unwrap();
    let load = LoadSpec {
        tenants: n_tenants, pauli: spec, seed: 9, ..LoadSpec::default()
    };
    quantum_peft::serve::serve_sharded(&rt, &cfg, &log, |router| {
        loadgen::populate_sharded(router, &load)?;
        let mut handles = Vec::new();
        for i in 0..n_tenants {
            handles.push(router.submit(
                &loadgen::tenant_name(i), i as u64, vec![0.25; spec.dim()])?);
        }
        router.flush();
        for h in handles {
            h.wait()?;
        }
        let victim = router.shard_of(&loadgen::tenant_name(0));
        let victim_tenants: Vec<String> = (0..n_tenants)
            .map(loadgen::tenant_name)
            .filter(|t| router.shard_of(t) == victim)
            .collect();
        router.kill_shard(victim)?;
        // the dump rode the session end: every span the victim served
        // is a serve_trace line already, each ok and latency-stamped
        let text = std::fs::read_to_string(&path).unwrap();
        let traces: Vec<Json> = text.lines()
            .map(|l| Json::parse(l).unwrap())
            .filter(|j| j.get("event").unwrap().as_str().unwrap() == "serve_trace")
            .collect();
        assert_eq!(traces.len(), victim_tenants.len(),
                   "expected one span per victim-shard request");
        for t in &traces {
            let tenant = t.get("tenant").unwrap().as_str().unwrap().to_string();
            assert!(victim_tenants.contains(&tenant), "{tenant}");
            assert!(matches!(t.get("ok").unwrap(), Json::Bool(true)));
            assert_eq!(t.get("trace").unwrap().as_str().unwrap().len(), 16);
            let phases = t.get("phases").unwrap().as_arr().unwrap();
            assert!(!phases.is_empty());
        }
        Ok(())
    })
    .unwrap();
    let _ = std::fs::remove_file(&path);
}

#[test]
fn response_log_sorts_by_meta() {
    use quantum_peft::serve::Response;
    let r = |meta: u64| Response {
        meta,
        tenant: "t".into(),
        version: 1,
        checksum: 9,
        output: vec![1.0],
        latency_us: 1.0,
    };
    let log = response_log(&[r(2), r(0), r(1)]);
    let metas: Vec<&str> = log.lines()
        .map(|l| l.split_whitespace().next().unwrap())
        .collect();
    assert_eq!(metas, vec!["meta=0", "meta=1", "meta=2"]);
}
