//! Integration tests for the durable serving state store (ISSUE 5):
//! the crash-injection matrix — the WAL truncated at every record
//! boundary and at several mid-record offsets in the tail — with
//! recovery reconstructing exactly the state of the last complete
//! record; typed corruption errors for anything a crash cannot explain;
//! snapshot compaction equivalence; and the end-to-end acceptance
//! property: a recovered server's fifo-mode response log is
//! byte-identical to an uninterrupted run over the same surviving
//! tenants.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use quantum_peft::coordinator::events::EventLog;
use quantum_peft::runtime::Runtime;
use quantum_peft::serve::loadgen::response_log;
use quantum_peft::serve::registry::theta_checksum;
use quantum_peft::serve::scheduler::BatchPolicy;
use quantum_peft::serve::{PauliSpec, Registry, ServeConfig};
use quantum_peft::store::{
    recover, CorruptState, Durability, StateRecord, StateStore, TenantState,
    WAL_FILE,
};
use quantum_peft::util::rng::Rng;

fn tdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join("qp_store_e2e")
        .join(format!("{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

const SPEC: PauliSpec = PauliSpec { q: 3, n_layers: 1 };

fn thetas_for(salt: u64) -> Vec<f32> {
    let mut rng = Rng::new(0x57a7_e000 ^ salt);
    (0..SPEC.num_params()).map(|_| rng.normal() as f32 * 0.5).collect()
}

fn tstate(tenant: &str, version: u64, salt: u64) -> TenantState {
    let thetas = thetas_for(salt);
    TenantState {
        tenant: tenant.to_string(),
        version,
        q: SPEC.q,
        n_layers: SPEC.n_layers,
        checksum: theta_checksum(&thetas),
        path: String::new(),
        thetas,
    }
}

/// The six-mutation script the crash matrix cuts apart.
fn script() -> Vec<StateRecord> {
    vec![
        StateRecord::Register(tstate("alpha", 1, 1)),
        StateRecord::Register(tstate("beta", 1, 2)),
        StateRecord::Swap(tstate("alpha", 2, 3)),
        StateRecord::Evict { tenant: "beta".to_string() },
        StateRecord::Register(tstate("gamma", 1, 4)),
        StateRecord::Swap(tstate("gamma", 2, 5)),
    ]
}

/// Reference replay: the state after the first `k` script records.
fn expected_after(k: usize) -> Vec<TenantState> {
    let mut state: BTreeMap<String, TenantState> = BTreeMap::new();
    for rec in script().into_iter().take(k) {
        match rec {
            StateRecord::Register(ts) | StateRecord::Swap(ts) => {
                state.insert(ts.tenant.clone(), ts);
            }
            StateRecord::Evict { tenant } => {
                state.remove(&tenant);
            }
        }
    }
    state.into_values().collect()
}

/// Append the script through a real store, capturing the WAL byte
/// length at every record boundary. Returns (full WAL bytes,
/// boundaries) with boundaries[k] = length after k records.
fn build_wal(dir: &Path) -> (Vec<u8>, Vec<u64>) {
    let store = StateStore::open(dir, Durability::Buffered).unwrap().store;
    let wal_path = dir.join(WAL_FILE);
    let mut boundaries =
        vec![std::fs::metadata(&wal_path).unwrap().len()];
    for rec in &script() {
        store.append(rec).unwrap();
        boundaries.push(std::fs::metadata(&wal_path).unwrap().len());
    }
    drop(store);
    (std::fs::read(&wal_path).unwrap(), boundaries)
}

/// Write `bytes` as the WAL of a fresh directory and recover it.
fn recover_bytes(name: &str, bytes: &[u8]) -> quantum_peft::store::RecoveredState {
    let dir = tdir(name);
    std::fs::write(dir.join(WAL_FILE), bytes).unwrap();
    recover(&dir).unwrap()
}

#[test]
fn crash_matrix_truncation_at_every_boundary_and_mid_record() {
    let dir = tdir("matrix_src");
    let (bytes, boundaries) = build_wal(&dir);
    assert_eq!(boundaries.len(), 7);
    assert_eq!(*boundaries.last().unwrap() as usize, bytes.len());

    // clean cuts: at every record boundary the recovered state is
    // exactly the replay of the surviving prefix, with no torn tail
    for (k, &b) in boundaries.iter().enumerate() {
        let r = recover_bytes("matrix_clean", &bytes[..b as usize]);
        assert!(!r.torn_tail, "k={k}");
        assert_eq!(r.tenants, expected_after(k), "k={k}");
        assert_eq!(r.wal_records, k as u64, "k={k}");
        assert_eq!(r.wal_valid_len, b, "k={k}");
    }

    // mid-record cuts: every truncation strictly inside record k+1 is a
    // torn tail; recovery reconstructs the state of the last complete
    // record (k of them) and reports the tear
    for k in 0..6usize {
        let lo = boundaries[k];
        let hi = boundaries[k + 1];
        let cuts = [lo + 1, lo + 4, lo + 8, lo + 9, (lo + hi) / 2, hi - 1];
        for &cut in &cuts {
            if cut <= lo || cut >= hi {
                continue;
            }
            let r = recover_bytes("matrix_torn", &bytes[..cut as usize]);
            assert!(r.torn_tail, "k={k} cut={cut}");
            assert_eq!(r.tenants, expected_after(k), "k={k} cut={cut}");
            assert_eq!(r.wal_valid_len, lo, "k={k} cut={cut}");
        }
    }
}

#[test]
fn open_truncates_the_torn_tail_and_the_log_continues_cleanly() {
    let dir = tdir("torn_continue");
    let (bytes, boundaries) = build_wal(&tdir("torn_src"));
    // cut inside the 5th record: four complete records survive
    let cut = (boundaries[4] + boundaries[5]) / 2;
    std::fs::write(dir.join(WAL_FILE), &bytes[..cut as usize]).unwrap();
    let opened = StateStore::open(&dir, Durability::Buffered).unwrap();
    assert!(opened.recovered.torn_tail);
    assert_eq!(opened.recovered.tenants, expected_after(4));
    assert_eq!(opened.recovered.last_seq, 4);
    // the torn bytes are gone from disk and appends restart at a clean
    // boundary with the next sequence number
    assert_eq!(
        std::fs::metadata(dir.join(WAL_FILE)).unwrap().len(),
        boundaries[4]
    );
    let seq = opened
        .store
        .append(&StateRecord::Register(tstate("delta", 1, 9)))
        .unwrap();
    assert_eq!(seq, 5);
    drop(opened.store);
    let r = recover(&dir).unwrap();
    assert!(!r.torn_tail);
    let mut want = expected_after(4);
    want.push(tstate("delta", 1, 9));
    want.sort_by(|a, b| a.tenant.cmp(&b.tenant));
    assert_eq!(r.tenants, want);
}

#[test]
fn interior_corruption_is_a_typed_error_not_a_silent_prefix() {
    let (bytes, boundaries) = build_wal(&tdir("corrupt_src"));
    // flip one byte inside record 2 — complete records follow, so this
    // is corruption, never a tolerated tear
    let dir = tdir("corrupt");
    let mut bad = bytes.clone();
    let pos = (boundaries[1] + 10) as usize;
    bad[pos] ^= 0xff;
    std::fs::write(dir.join(WAL_FILE), &bad).unwrap();
    let e = recover(&dir).unwrap_err();
    let c = e.downcast_ref::<CorruptState>()
        .unwrap_or_else(|| panic!("untyped corruption error: {e}"));
    assert_eq!(c.offset, boundaries[1]);
    // and StateStore::open refuses the directory the same way
    let e = StateStore::open(&dir, Durability::Buffered).unwrap_err();
    assert!(e.downcast_ref::<CorruptState>().is_some(), "{e}");

    // a corrupted length prefix mid-file is corruption too (the frame
    // CRC covers the length field): shrink record 2's claimed length
    let dir = tdir("corrupt_len");
    let mut bad = bytes.clone();
    let len_pos = boundaries[1] as usize;
    let len = u32::from_le_bytes(bad[len_pos..len_pos + 4].try_into().unwrap());
    bad[len_pos..len_pos + 4].copy_from_slice(&(len - 1).to_le_bytes());
    std::fs::write(dir.join(WAL_FILE), &bad).unwrap();
    let e = recover(&dir).unwrap_err();
    assert!(e.downcast_ref::<CorruptState>().is_some(), "{e}");

    // a length corrupted to reach past EOF while the trailing bytes
    // still fit inside one frame cap is indistinguishable from a torn
    // append by construction: recovery reports a torn tail with the
    // pre-corruption prefix — degraded, but deterministic and never a
    // panic or a silent mid-log skip
    let dir = tdir("corrupt_len_eof");
    let mut bad = bytes.clone();
    let len_pos = boundaries[1] as usize;
    bad[len_pos..len_pos + 4]
        .copy_from_slice(&(1u32 << 20).to_le_bytes());
    std::fs::write(dir.join(WAL_FILE), &bad).unwrap();
    let r = recover(&dir).unwrap();
    assert!(r.torn_tail);
    assert_eq!(r.tenants, expected_after(1));
}

#[test]
fn compaction_preserves_state_and_bounds_the_replay() {
    let dir = tdir("compact_equiv");
    let store =
        Arc::new(StateStore::open(&dir, Durability::Buffered).unwrap().store);
    let reg = Registry::new(1 << 20).with_state_sink(store.clone());
    for i in 0..24u64 {
        let name = format!("tenant{:02}", i % 8);
        let t = thetas_for(100 + i);
        reg.register(&name, SPEC, t).unwrap();
    }
    reg.evict_tenant("tenant07").unwrap();
    let before = reg.export_state();
    assert_eq!(before.len(), 7);
    // compact: 25 WAL records become one 7-entry snapshot
    reg.compact_into(&store).unwrap();
    assert_eq!(store.wal_records(), 0);
    // post-compaction mutations keep appending after the snapshot
    reg.register("late", SPEC, thetas_for(999)).unwrap();
    let after = reg.export_state();
    drop(reg);
    drop(store);
    let opened = StateStore::open(&dir, Durability::Buffered).unwrap();
    let r = &opened.recovered;
    assert_eq!(r.snapshot_entries, 7);
    assert_eq!(r.wal_records, 1);
    assert_eq!(r.tenants, after);
    assert_eq!(r.last_seq, 26);
}

// ------------------------------------------------ serving byte-identity ---

/// Tenants the byte-identity scenario registers, in order.
const TENANTS: [&str; 4] = ["t-a", "t-b", "t-c", "t-d"];

/// A fixed 32-submission schedule over the four tenants. Input payloads
/// are a pure function of the request meta, so any registry serving the
/// same adapter bits must produce the same response log.
fn schedule() -> Vec<(usize, u64)> {
    let mut picks = Rng::new(0x5c4ed);
    (0..32u64).map(|meta| (picks.below(TENANTS.len()), meta)).collect()
}

fn request_input(meta: u64) -> Vec<f32> {
    let mut rng = Rng::new(0x1a9 ^ meta.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    (0..SPEC.dim()).map(|_| rng.normal() as f32 * 0.5).collect()
}

/// Run the fixed schedule through a fifo serve session, skipping
/// submissions to tenants outside `alive`, and return the canonical
/// response log.
fn run_session(reg: &Registry, alive: &[String]) -> String {
    let rt = Runtime::cpu().unwrap();
    let cfg = ServeConfig {
        workers: 4,
        policy: BatchPolicy { max_batch: 3, max_wait_us: 1 },
        fifo: true,
        ..ServeConfig::default()
    };
    let outcome = quantum_peft::serve::serve(
        &rt, reg, &cfg, &EventLog::null(), |h| {
            let mut handles = Vec::new();
            for (t, meta) in schedule() {
                let name = TENANTS[t];
                if !alive.iter().any(|a| a == name) {
                    continue;
                }
                handles.push(h.submit(name, meta, request_input(meta))?);
            }
            h.flush();
            handles.into_iter().map(|h| h.wait()).collect::<Result<Vec<_>, _>>()
        })
        .unwrap();
    response_log(&outcome.body)
}

#[test]
fn recovered_server_serves_byte_identical_responses() {
    let dir = tdir("identity");
    let wal_path = dir.join(WAL_FILE);

    // --- original process: durable registrations, then traffic
    let store =
        Arc::new(StateStore::open(&dir, Durability::Buffered).unwrap().store);
    let reg = Registry::new(1 << 20).with_state_sink(store.clone());
    let mut boundaries = vec![std::fs::metadata(&wal_path).unwrap().len()];
    for (i, name) in TENANTS.iter().enumerate() {
        reg.register(name, SPEC, thetas_for(50 + i as u64)).unwrap();
        boundaries.push(std::fs::metadata(&wal_path).unwrap().len());
    }
    let all: Vec<String> = TENANTS.iter().map(|s| s.to_string()).collect();
    let log_full = run_session(&reg, &all);
    assert!(!log_full.is_empty());
    let wal_bytes = std::fs::read(&wal_path).unwrap();
    drop(reg);
    drop(store);

    // --- clean restart: full recovery reproduces the exact log
    let opened = StateStore::open(&dir, Durability::Buffered).unwrap();
    assert_eq!(opened.recovered.tenants.len(), TENANTS.len());
    let reg2 = Registry::new(1 << 20);
    for ts in &opened.recovered.tenants {
        reg2.restore(ts).unwrap();
    }
    assert_eq!(run_session(&reg2, &all), log_full);
    drop(opened.store);

    // --- crash restart: the WAL torn mid-way through the last
    // registration loses exactly that tenant; the recovered server's
    // log over the survivors is byte-identical to an uninterrupted
    // control run over the same survivors
    let cut = (boundaries[3] + boundaries[4]) / 2;
    let crash_dir = tdir("identity_crash");
    std::fs::write(crash_dir.join(WAL_FILE), &wal_bytes[..cut as usize])
        .unwrap();
    let opened = StateStore::open(&crash_dir, Durability::Buffered).unwrap();
    assert!(opened.recovered.torn_tail);
    let survivors: Vec<String> = opened
        .recovered
        .tenants
        .iter()
        .map(|t| t.tenant.clone())
        .collect();
    assert_eq!(survivors, vec!["t-a", "t-b", "t-c"]);
    let reg3 = Registry::new(1 << 20);
    for ts in &opened.recovered.tenants {
        reg3.restore(ts).unwrap();
    }
    let log_recovered = run_session(&reg3, &survivors);

    // control: a never-crashed registry holding only the survivors
    let control = Registry::new(1 << 20);
    for (i, name) in TENANTS.iter().take(3).enumerate() {
        control.register(name, SPEC, thetas_for(50 + i as u64)).unwrap();
    }
    let log_control = run_session(&control, &survivors);
    assert_eq!(log_recovered, log_control,
               "recovered server diverged from the uninterrupted control");
    // and losing a tenant really changed the workload vs the full run
    assert_ne!(log_recovered, log_full);
}

#[test]
fn serve_bench_restart_recovers_and_repeats_byte_identically() {
    use quantum_peft::serve::{BenchOpts, LoadSpec};
    let dir = tdir("bench_restart");
    let opts = BenchOpts {
        load: LoadSpec {
            tenants: 6,
            requests: 96,
            concurrency: 16,
            seed: 21,
            zipf_s: 1.0,
            pauli: SPEC,
            open_rate_rps: 0.0,
        },
        serve: ServeConfig {
            workers: 2,
            policy: BatchPolicy { max_batch: 4, max_wait_us: 1 },
            fifo: true,
            ..ServeConfig::default()
        },
        cache_bytes: 1 << 20,
        state_dir: Some(dir.clone()),
        ..BenchOpts::default()
    };
    let (s1, log1) =
        quantum_peft::serve::run_serve_bench(&opts, &EventLog::null()).unwrap();
    assert_eq!(s1.completed, 96);
    // session end compacted the log: a snapshot exists
    assert!(dir.join(quantum_peft::store::SNAPSHOT_FILE).exists());
    // "restart": the same bench against the same state dir recovers the
    // six tenants (populate skips them) and replays the identical
    // workload byte-for-byte
    let (s2, log2) =
        quantum_peft::serve::run_serve_bench(&opts, &EventLog::null()).unwrap();
    assert_eq!(s2.completed, 96);
    assert_eq!(log2, log1, "restarted server diverged");
    // recovery really happened: versions stayed at 1 (a re-register
    // would have bumped them to 2 and changed the response log)
    assert!(log2.contains("version=1"));
    assert!(!log2.contains("version=2"));
}
