//! Parallel sweep engine: the determinism regression guard (jobs=1 vs
//! jobs=N must produce byte-identical results and aggregates) plus
//! concurrency edge cases, all through the public `run_plan_with` API
//! with a synthetic cell runner — no artifacts required.

use std::collections::BTreeMap;
use std::time::Duration;

use quantum_peft::coordinator::events::EventLog;
use quantum_peft::coordinator::sweep::{self, Cell, SweepPlan};
use quantum_peft::coordinator::trainer::{RunResult, TrainConfig};
use quantum_peft::data::glue;
use quantum_peft::util::json::Json;
use quantum_peft::util::rng::Rng;

fn plan(tags: &[&str], tasks: Vec<glue::Task>, seeds: Vec<u64>) -> SweepPlan {
    SweepPlan {
        tags: tags.iter().map(|s| s.to_string()).collect(),
        tasks,
        seeds,
        cfg: TrainConfig::default(),
        backbone: None,
        task_lr: BTreeMap::new(),
    }
}

/// Deterministic stand-in for `trainer::run_glue`: the metric is a pure
/// function of (tag, task, seed), like a real run with isolated RNG
/// streams; the sleep scrambles completion order across workers.
fn fake_run(cell: &Cell, cfg: &TrainConfig, sleep: bool) -> RunResult {
    let tag_hash: u64 = cell.tag.bytes().map(|b| b as u64).sum();
    let task_hash: u64 = cell.task.name().bytes().map(|b| b as u64).sum();
    let mut rng = Rng::new(cfg.seed ^ (tag_hash << 16) ^ (task_hash << 32));
    let metric = rng.f64();
    if sleep {
        std::thread::sleep(Duration::from_millis(rng.below(8) as u64));
    }
    RunResult {
        tag: cell.tag.clone(),
        task: cell.task.name().to_string(),
        metric_name: cell.task.metric_name().to_string(),
        best_metric: metric,
        final_metric: metric,
        losses: vec![],
        adapter_params: 100 + tag_hash as usize,
        trainable_params: 200 + tag_hash as usize,
        wall_seconds: 0.0,
        step_ms: (cfg.seed + 1) as f64,
        extra_metrics: BTreeMap::new(),
    }
}

fn run_with_jobs(p: &SweepPlan, jobs: usize, log: &EventLog) -> Vec<RunResult> {
    sweep::run_plan_with(p, jobs, log, |_w| Ok(()),
                         |_s, cell, cfg, _wlog| Ok(fake_run(cell, &cfg, jobs > 1)))
        .unwrap()
}

fn assert_identical(a: &[RunResult], b: &[RunResult]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.tag, y.tag);
        assert_eq!(x.task, y.task);
        assert_eq!(x.metric_name, y.metric_name);
        // bit-exact, not approximately equal: the determinism contract
        assert_eq!(x.best_metric.to_bits(), y.best_metric.to_bits());
        assert_eq!(x.final_metric.to_bits(), y.final_metric.to_bits());
        assert_eq!(x.adapter_params, y.adapter_params);
        assert_eq!(x.trainable_params, y.trainable_params);
    }
}

#[test]
fn jobs_1_and_jobs_4_are_byte_identical() {
    let p = plan(&["enc_qpeft_pauli", "enc_lora"],
                 vec![glue::Task::Sst2, glue::Task::Cola],
                 vec![0, 1, 2]);
    let log = EventLog::null();
    let seq = run_with_jobs(&p, 1, &log);
    assert_eq!(seq.len(), 12);
    for jobs in [2, 4, 16] {
        let par = run_with_jobs(&p, jobs, &log);
        assert_identical(&seq, &par);
        // aggregates must match exactly too: order, means, stds
        let a_seq = sweep::aggregate(&seq);
        let a_par = sweep::aggregate(&par);
        assert_eq!(a_seq.len(), a_par.len());
        for (x, y) in a_seq.iter().zip(&a_par) {
            assert_eq!((&x.tag, &x.task), (&y.tag, &y.task));
            assert_eq!(x.mean_metric.to_bits(), y.mean_metric.to_bits());
            assert_eq!(x.std_metric.to_bits(), y.std_metric.to_bits());
            assert_eq!(x.n_seeds, y.n_seeds);
        }
    }
}

#[test]
fn results_follow_plan_cell_order_not_completion_order() {
    let p = plan(&["a", "b", "c"], vec![glue::Task::Rte], vec![0, 1]);
    let cells = p.cells();
    let results = run_with_jobs(&p, 4, &EventLog::null());
    assert_eq!(results.len(), cells.len());
    for (cell, r) in cells.iter().zip(&results) {
        assert_eq!(cell.tag, r.tag);
        assert_eq!(cell.task.name(), r.task);
    }
}

#[test]
fn more_jobs_than_cells() {
    let p = plan(&["only"], vec![glue::Task::Sst2], vec![0]);
    let results = run_with_jobs(&p, 32, &EventLog::null());
    assert_eq!(results.len(), 1);
    assert_eq!(results[0].tag, "only");
}

#[test]
fn empty_plan_is_empty_not_hung() {
    let p = plan(&[], vec![glue::Task::Sst2], vec![0, 1]);
    let results = run_with_jobs(&p, 4, &EventLog::null());
    assert!(results.is_empty());
    assert!(sweep::aggregate(&results).is_empty());
    // empty task / seed axes too
    let p = plan(&["t"], vec![], vec![0]);
    assert!(run_with_jobs(&p, 4, &EventLog::null()).is_empty());
}

#[test]
fn panicking_cell_surfaces_as_error_not_hang() {
    let p = plan(&["ok", "bad"], vec![glue::Task::Sst2], vec![0, 1]);
    let err = sweep::run_plan_with(
        &p, 4, &EventLog::null(), |_w| Ok(()),
        |_s, cell, cfg, _wlog| {
            if cell.tag == "bad" {
                panic!("cell exploded: {}-{}", cell.tag, cell.seed);
            }
            Ok(fake_run(cell, &cfg, false))
        })
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("panicked"), "{msg}");
    assert!(msg.contains("cell exploded"), "{msg}");
}

#[test]
fn failing_cell_is_a_deterministic_error() {
    let p = plan(&["a", "b"], vec![glue::Task::Sst2], vec![0, 1]);
    for jobs in [1, 4] {
        let err = sweep::run_plan_with(
            &p, jobs, &EventLog::null(), |_w| Ok(()),
            |_s, cell, cfg, _wlog| {
                if cell.tag == "b" && cell.seed == 0 {
                    anyhow::bail!("cell b/0 refused");
                }
                Ok(fake_run(cell, &cfg, false))
            })
            .unwrap_err();
        // fail-fast pool: whichever cell's error surfaces (the failure
        // itself or a skip it caused), the message names the root cause
        assert!(err.to_string().contains("cell b/0 refused"), "{err}");
    }
}

#[test]
fn parallel_sweep_logs_worker_tagged_lifecycle_events() {
    let path = std::env::temp_dir().join("qp_sweep_parallel_events.jsonl");
    let _ = std::fs::remove_file(&path);
    let log = EventLog::new(Some(path.clone()), false).unwrap();
    let p = plan(&["x", "y"], vec![glue::Task::Sst2, glue::Task::Cola],
                 vec![0, 1, 2]);
    let n_cells = p.cells().len();
    run_with_jobs(&p, 3, &log);
    let text = std::fs::read_to_string(&path).unwrap();
    let mut starts = 0;
    let mut dones = 0;
    for line in text.lines() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("bad line {line:?}: {e}"));
        match j.get("event").unwrap().as_str().unwrap() {
            "cell_start" => {
                starts += 1;
                assert!(j.get("worker").unwrap().as_usize().unwrap() < 3);
                assert!(j.get("i").unwrap().as_usize().unwrap() < n_cells);
            }
            "cell_done" => {
                dones += 1;
                assert!(j.get("worker").unwrap().as_usize().unwrap() < 3);
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    assert_eq!(starts, n_cells);
    assert_eq!(dones, n_cells);
}
