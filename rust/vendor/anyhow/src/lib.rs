//! Offline stand-in for the `anyhow` crate, API-compatible with the subset
//! this repository uses: `Result`, `Error`, the `Context` extension trait
//! on `Result`/`Option`, the `anyhow!` / `bail!` macros, and
//! `downcast_ref` for recovering typed errors (e.g. the serve admission
//! controller's `Rejected`).
//!
//! The build image has no crates.io access, so the dependency is vendored
//! as a path crate (see rust/Cargo.toml). Swapping in the real `anyhow`
//! later is a one-line Cargo.toml change; no call sites need to move.
//!
//! Semantics match real anyhow where it matters:
//! - `Error` does NOT implement `std::error::Error` (this is what makes
//!   the blanket `From<E: std::error::Error>` impl coherent alongside the
//!   identity `From<Error>` used by `?`);
//! - `.context(..)` wraps the prior error, and `Display` shows the chain
//!   outermost-first (`"outer: inner"`), `Debug` shows a Caused-by list;
//! - a typed error that entered the chain through `?`/`From` stays
//!   reachable via `downcast_ref` no matter how much context wraps it.

use std::fmt;

/// `Result` with a defaulted error type, as in real anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error chain.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
    /// The concrete error value the chain was built from, when it entered
    /// through the `From<E: std::error::Error>` conversion — what makes
    /// `downcast_ref` work across context wrapping.
    payload: Option<Box<dyn std::any::Any + Send + Sync>>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), cause: None, payload: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), cause: Some(Box::new(self)), payload: None }
    }

    /// A reference to the typed error `T` anywhere in this chain, if one
    /// entered through `From`/`?` — context wrapping does not hide it
    /// (matching real anyhow's downcast-through-context behavior).
    pub fn downcast_ref<T: std::any::Any>(&self) -> Option<&T> {
        let mut cur = Some(self);
        while let Some(e) = cur {
            if let Some(t) = e.payload.as_deref().and_then(|p| p.downcast_ref::<T>()) {
                return Some(t);
            }
            cur = e.cause.as_deref();
        }
        None
    }

    /// The error chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut items = vec![self.msg.as_str()];
        let mut cur = self.cause.as_deref();
        while let Some(e) = cur {
            items.push(e.msg.as_str());
            cur = e.cause.as_deref();
        }
        items.into_iter()
    }

    /// The innermost message (root cause).
    pub fn root_cause(&self) -> &str {
        self.chain().last().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, m) in self.chain().enumerate() {
            if i > 0 {
                write!(f, ": ")?;
            }
            write!(f, "{m}")?;
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.cause.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.cause.as_deref();
        }
        Ok(())
    }
}

/// Any std error converts into `Error`, flattening its source chain. This
/// is what makes `?` work on io/parse/utf8/... results inside functions
/// returning `anyhow::Result`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = Vec::new();
        let mut cur: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = cur {
            msgs.push(s.to_string());
            cur = s.source();
        }
        let mut cause = None;
        for m in msgs.into_iter().rev() {
            cause = Some(Box::new(Error { msg: m, cause, payload: None }));
        }
        Error { msg: e.to_string(), cause, payload: Some(Box::new(e)) }
    }
}

// -- Context extension trait (the anyhow ext-trait pattern) ---------------

mod ext {
    /// Sealed adapter: anything that can become an `Error`. The blanket
    /// impl for std errors and the concrete impl for `Error` are coherent
    /// because `Error` never implements `std::error::Error` (same trick
    /// real anyhow uses in its ext module).
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: ext::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

// -- macros ---------------------------------------------------------------

/// `anyhow!("fmt {args}")` — construct an ad-hoc `Error`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `bail!(...)` — early-return `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn context_chains_and_displays() {
        let r: Result<()> = io_err().context("opening config");
        let e = r.unwrap_err();
        let s = format!("{e}");
        assert!(s.starts_with("opening config"), "{s}");
        assert!(s.contains("gone"), "{s}");
    }

    #[test]
    fn with_context_on_option() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(format!("{e}"), "missing x");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<u32> {
            let n: u32 = "not a number".parse()?;
            Ok(n)
        }
        assert!(f().is_err());
    }

    #[test]
    fn macros_work() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {flag}");
            }
            Err(anyhow!("fell through {}", 42))
        }
        assert_eq!(format!("{}", f(true).unwrap_err()), "flag was true");
        assert_eq!(format!("{}", f(false).unwrap_err()), "fell through 42");
    }

    #[test]
    fn downcast_ref_survives_context_wrapping() {
        #[derive(Debug, PartialEq)]
        struct Typed(u32);
        impl fmt::Display for Typed {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "typed error {}", self.0)
            }
        }
        impl std::error::Error for Typed {}

        fn inner() -> Result<()> {
            Err(Typed(7))?;
            Ok(())
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: typed error 7");
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed(7)));
        assert!(e.downcast_ref::<std::io::Error>().is_none());
        // ad-hoc string errors carry no payload
        assert!(anyhow!("plain").downcast_ref::<Typed>().is_none());
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: inner");
        assert_eq!(e.root_cause(), "inner");
    }
}
