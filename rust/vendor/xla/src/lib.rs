//! Offline stub of the `xla` (xla-rs / xla_extension) PJRT bindings.
//!
//! The build image does not ship the native XLA closure, so this crate
//! provides the exact API surface the coordinator uses:
//!
//! - `Literal` is FULLY FUNCTIONAL as a host-side container (scalar/vec1/
//!   reshape/to_vec/array_shape round-trips work), so all marshalling code
//!   and its tests behave identically to the real bindings;
//! - `PjRtClient::cpu()` succeeds (it is just a host handle), but
//!   `compile`/`execute`/`from_text_file` return a clear "bindings
//!   unavailable" error, so artifact-dependent paths fail loudly at run
//!   time instead of at link time.
//!
//! Replacing this stub with the real bindings is a Cargo.toml swap; no
//! call sites change.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: native XLA/PJRT bindings are not available in this build \
         (the `xla` crate is an offline stub — see rust/vendor/xla)"
    ))
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Host element types the coordinator marshals (f32 / i32).
pub trait NativeType: Copy + Sized {
    const TY: ElementType;
    fn to_buf(v: Vec<Self>) -> Buf;
    fn from_buf(b: &Buf) -> Option<Vec<Self>>;
}

#[derive(Clone, Debug, PartialEq)]
pub enum Buf {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Buf {
    fn len(&self) -> usize {
        match self {
            Buf::F32(v) => v.len(),
            Buf::I32(v) => v.len(),
        }
    }

    fn ty(&self) -> ElementType {
        match self {
            Buf::F32(_) => ElementType::F32,
            Buf::I32(_) => ElementType::S32,
        }
    }
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn to_buf(v: Vec<f32>) -> Buf {
        Buf::F32(v)
    }
    fn from_buf(b: &Buf) -> Option<Vec<f32>> {
        match b {
            Buf::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn to_buf(v: Vec<i32>) -> Buf {
        Buf::I32(v)
    }
    fn from_buf(b: &Buf) -> Option<Vec<i32>> {
        match b {
            Buf::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
enum Repr {
    Array { dims: Vec<i64>, buf: Buf },
    Tuple(Vec<Literal>),
}

/// Host literal: a typed buffer with dims, or a tuple of literals.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal(Repr);

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal(Repr::Array { dims: vec![], buf: T::to_buf(vec![v]) })
    }

    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal(Repr::Array {
            dims: vec![v.len() as i64],
            buf: T::to_buf(v.to_vec()),
        })
    }

    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal(Repr::Tuple(elems))
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match &self.0 {
            Repr::Array { buf, .. } => {
                let numel: i64 = dims.iter().product();
                if numel as usize != buf.len() {
                    return Err(Error(format!(
                        "reshape to {dims:?} ({numel} elements) from buffer of {}",
                        buf.len()
                    )));
                }
                Ok(Literal(Repr::Array { dims: dims.to_vec(), buf: buf.clone() }))
            }
            Repr::Tuple(_) => Err(Error("cannot reshape a tuple literal".into())),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match &self.0 {
            Repr::Array { dims, buf } => {
                Ok(ArrayShape { dims: dims.clone(), ty: buf.ty() })
            }
            Repr::Tuple(_) => Err(Error("tuple literal has no array shape".into())),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match &self.0 {
            Repr::Array { buf, .. } => T::from_buf(buf).ok_or_else(|| {
                Error(format!("literal holds {:?}, not {:?}", buf.ty(), T::TY))
            }),
            Repr::Tuple(_) => Err(Error("tuple literal has no flat buffer".into())),
        }
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.0 {
            Repr::Tuple(elems) => Ok(elems),
            Repr::Array { .. } => Err(Error("literal is not a tuple".into())),
        }
    }
}

#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

// -- PJRT handles (constructible, but compile/execute are unavailable) ----

#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let p = path.as_ref();
        if !p.exists() {
            return Err(Error(format!("HLO text file {p:?} does not exist")));
        }
        Err(unavailable(&format!("parsing HLO text {p:?}")))
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu (xla stub — PJRT unavailable)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("XLA compile"))
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PJRT execute"))
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PJRT buffer readback"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_scalar_and_vec_roundtrip() {
        let s = Literal::scalar(2.5f32);
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![2.5]);
        assert_eq!(s.array_shape().unwrap().dims(), &[] as &[i64]);

        let v = Literal::vec1(&[1i32, 2, 3, 4]);
        let r = v.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.array_shape().unwrap().ty(), ElementType::S32);
        assert_eq!(r.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn reshape_rejects_bad_numel() {
        assert!(Literal::vec1(&[1.0f32, 2.0]).reshape(&[3]).is_err());
    }

    #[test]
    fn type_mismatch_is_an_error() {
        assert!(Literal::vec1(&[1i32]).to_vec::<f32>().is_err());
    }

    #[test]
    fn tuple_roundtrip() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::scalar(2i32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(0i32).to_tuple().is_err());
    }

    #[test]
    fn pjrt_paths_fail_loudly() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        assert!(client.compile(&XlaComputation).is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo").is_err());
    }
}
